//! Client-wise slicing of a problem (paper Fig. 1).

use super::Problem;
use crate::linalg::{Domain, Mat};

/// What client `j` privately owns in the all-to-all regime:
/// its marginal slices plus both kernel blocks. In the log domain the
/// kernel blocks hold `log K` entries and the exchanged state is the
/// log-scaling slice — exactly the quantity the paper's privacy layer
/// instruments.
#[derive(Clone, Debug)]
pub struct ClientShard {
    /// Client index.
    pub id: usize,
    /// Global row range `[r0, r1)` of this client's block.
    pub r0: usize,
    pub r1: usize,
    /// `a_j` (length m).
    pub a: Vec<f64>,
    /// `b_j` (m × N).
    pub b: Mat,
    /// Row block `K_j = K[r0..r1, :]` (m × n) — `log K` rows in the log
    /// domain.
    pub k_row: Mat,
    /// Transposed column block `K[:, r0..r1]ᵀ` (m × n) — the operator of
    /// the v-update `r_j = K_jᵀ u`; `(log K)ᵀ` rows in the log domain.
    pub k_col_t: Mat,
}

impl ClientShard {
    pub fn m(&self) -> usize {
        self.r1 - self.r0
    }
}

/// An `n = c·m` problem partitioned across `c` clients.
#[derive(Clone, Debug)]
pub struct Partition {
    pub n: usize,
    pub clients: usize,
    /// Representation the kernel blocks (and the exchanged scaling
    /// slices) use.
    pub domain: Domain,
    pub shards: Vec<ClientShard>,
}

impl Partition {
    /// Linear-domain slicing; requires `c | n` like the paper.
    pub fn new(p: &Problem, c: usize) -> Partition {
        Self::new_in(p, c, Domain::Linear)
    }

    /// Slice `p` across `c` clients in the given numerics domain. The
    /// transposed kernel comes from the problem's shared cache, so
    /// repartitioning (multi-solve experiments) never recomputes it.
    pub fn new_in(p: &Problem, c: usize, domain: Domain) -> Partition {
        assert!(c > 0 && p.n % c == 0, "clients must divide n (n={}, c={c})", p.n);
        let m = p.n / c;
        let k = p.kernel_for(domain);
        let kt = p.kernel_t_for(domain);
        let shards = (0..c)
            .map(|j| {
                let (r0, r1) = (j * m, (j + 1) * m);
                ClientShard {
                    id: j,
                    r0,
                    r1,
                    a: p.a[r0..r1].to_vec(),
                    b: p.b.row_block(r0, r1),
                    k_row: k.row_block(r0, r1),
                    k_col_t: kt.row_block(r0, r1),
                }
            })
            .collect();
        Partition { n: p.n, clients: c, domain, shards }
    }

    pub fn m(&self) -> usize {
        self.n / self.clients
    }
}
