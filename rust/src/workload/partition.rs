//! Client-wise slicing of a problem (paper Fig. 1).

use super::Problem;
use crate::linalg::Mat;

/// What client `j` privately owns in the all-to-all regime:
/// its marginal slices plus both kernel blocks.
#[derive(Clone, Debug)]
pub struct ClientShard {
    /// Client index.
    pub id: usize,
    /// Global row range `[r0, r1)` of this client's block.
    pub r0: usize,
    pub r1: usize,
    /// `a_j` (length m).
    pub a: Vec<f64>,
    /// `b_j` (m × N).
    pub b: Mat,
    /// Row block `K_j = K[r0..r1, :]` (m × n).
    pub k_row: Mat,
    /// Transposed column block `K[:, r0..r1]ᵀ` (m × n) — the operator of
    /// the v-update `r_j = K_jᵀ u`.
    pub k_col_t: Mat,
}

impl ClientShard {
    pub fn m(&self) -> usize {
        self.r1 - self.r0
    }
}

/// An `n = c·m` problem partitioned across `c` clients.
#[derive(Clone, Debug)]
pub struct Partition {
    pub n: usize,
    pub clients: usize,
    pub shards: Vec<ClientShard>,
}

impl Partition {
    /// Slice `p` across `c` clients; requires `c | n` like the paper.
    pub fn new(p: &Problem, c: usize) -> Partition {
        assert!(c > 0 && p.n % c == 0, "clients must divide n (n={}, c={c})", p.n);
        let m = p.n / c;
        let kt = p.k.transpose();
        let shards = (0..c)
            .map(|j| {
                let (r0, r1) = (j * m, (j + 1) * m);
                ClientShard {
                    id: j,
                    r0,
                    r1,
                    a: p.a[r0..r1].to_vec(),
                    b: p.b.row_block(r0, r1),
                    k_row: p.k.row_block(r0, r1),
                    k_col_t: kt.row_block(r0, r1),
                }
            })
            .collect();
        Partition { n: p.n, clients: c, shards }
    }

    pub fn m(&self) -> usize {
        self.n / self.clients
    }
}
