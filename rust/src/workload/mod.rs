//! Synthetic workload generation (paper §III–§IV) and problem slicing.
//!
//! Generates the `(a, b, C, K)` tuples the experiments consume:
//! * marginals `a, b` — Dirichlet simplex samples (strictly positive,
//!   summing to 1), or the paper's fixed 4-point example;
//! * cost families — the paper's circulant 4×4, squared-Euclidean on
//!   random supports, and random uniform costs;
//! * **off-diagonal block sparsity** `s ∈ {0, 0.5, 0.9, 1}` (§IV-D): a
//!   fraction `s` of the off-diagonal client-block pairs get their cost
//!   inflated so the Gibbs entries underflow toward 0;
//! * **condition classes** well/medium/ill (§IV-D) — the cost scale is
//!   chosen so `K = exp(−C/ε)` has benign, moderate or extreme dynamic
//!   range (its condition worsens as ε shrinks relative to cost spread);
//! * `N` target histograms (`b ∈ R^{n×N}`, Cuturi vectorization §IV-B3).
//!
//! A [`Problem`] stores the cost matrix and materializes `K`, `log K`,
//! both transposes, θ-truncated sparse log kernels (keyed per
//! threshold, with a density report), and zero-reference *absorbed*
//! kernels for the hybrid schedule (keyed per (θ, τ) tuning) lazily —
//! cached, shared across clones — so small-ε workloads never build an
//! underflowed linear kernel unless a linear solver asks for one, and
//! the stabilized engines truncate each kernel exactly once.
//!
//! [`Partition`] slices a problem across `c` clients exactly as the
//! paper's Fig. 1: client `j` owns `a_j, b_j`, row block `K_j` and the
//! transposed column block `K[:, j]ᵀ` — in either numerics domain.

mod generate;
mod partition;

pub use generate::{CondClass, Problem, ProblemSpec};
pub use partition::{ClientShard, Partition};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marginals_are_simplex_points() {
        let p = ProblemSpec::new(64).with_hists(3).build(7);
        assert!((p.a.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for h in 0..3 {
            let s: f64 = (0..64).map(|i| p.b[(i, h)]).sum();
            assert!((s - 1.0).abs() < 1e-12, "hist {h} sums to {s}");
        }
        assert!(p.a.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gibbs_kernel_positive_when_dense() {
        let p = ProblemSpec::new(32).build(1);
        assert!(p.kernel().as_slice().iter().all(|&x| x > 0.0));
    }

    #[test]
    fn sparsity_zeroes_offdiag_blocks() {
        let dense = ProblemSpec::new(64).with_sparsity(0.0, 4).build(3);
        let sparse = ProblemSpec::new(64).with_sparsity(1.0, 4).build(3);
        let count_small = |m: &crate::linalg::Mat| {
            m.as_slice().iter().filter(|&&x| x < 1e-100).count()
        };
        assert_eq!(count_small(dense.kernel()), 0);
        // s = 1: all 12 of 16 off-diagonal 16x16 blocks suppressed.
        assert_eq!(count_small(sparse.kernel()), 12 * 16 * 16);
    }

    #[test]
    fn condition_classes_order_dynamic_range() {
        let range = |c: CondClass| {
            let p = ProblemSpec::new(32).with_condition(c).build(5);
            let mx = p.kernel().as_slice().iter().cloned().fold(f64::MIN, f64::max);
            let mn = p.kernel().as_slice().iter().cloned().fold(f64::MAX, f64::min);
            mx / mn
        };
        let w = range(CondClass::Well);
        let m = range(CondClass::Medium);
        let i = range(CondClass::Ill);
        assert!(w < m && m < i, "ranges {w} {m} {i}");
    }

    #[test]
    fn partition_blocks_reassemble() {
        let p = ProblemSpec::new(24).with_hists(2).build(11);
        let part = Partition::new(&p, 4);
        assert_eq!(part.shards.len(), 4);
        for (j, sh) in part.shards.iter().enumerate() {
            let m = 24 / 4;
            assert_eq!(sh.k_row.rows(), m);
            assert_eq!(sh.k_col_t.rows(), m);
            // Row block matches the full kernel.
            for i in 0..m {
                for col in 0..24 {
                    assert_eq!(sh.k_row[(i, col)], p.kernel()[(j * m + i, col)]);
                    // k_col_t[i][col] = K[col][j*m + i]
                    assert_eq!(sh.k_col_t[(i, col)], p.kernel()[(col, j * m + i)]);
                }
            }
        }
    }

    #[test]
    fn log_kernel_stays_finite_where_linear_underflows() {
        // ε so small that exp(−C/ε) underflows every off-diagonal entry:
        // the log kernel is exact and no linear kernel is ever built.
        let p = Problem::paper_4x4(1e-3);
        let lk = p.log_kernel();
        assert_eq!(lk[(0, 0)], 0.0);
        assert_eq!(lk[(0, 3)], -3000.0);
        assert!(lk.as_slice().iter().all(|x| !x.is_nan()));
        // The transpose cache returns the same allocation on re-access.
        let t1 = p.log_kernel_t() as *const crate::linalg::Mat;
        let t2 = p.log_kernel_t() as *const crate::linalg::Mat;
        assert_eq!(t1, t2);
    }

    #[test]
    fn sparse_log_kernel_cache_and_density() {
        use std::sync::Arc;
        // s = 1: off-diagonal blocks carry cost 800·ε → log K = −800,
        // far below the row max − 60 truncation line; only the 4
        // diagonal 8×8 blocks survive.
        let p = ProblemSpec::new(32).with_sparsity(1.0, 4).build(9);
        let k1 = p.sparse_log_kernel(-60.0);
        let k2 = p.sparse_log_kernel(-60.0);
        assert!(Arc::ptr_eq(&k1, &k2), "cache must return the same allocation");
        assert!((p.sparse_log_density(-60.0) - 0.25).abs() < 1e-12);
        let t = p.sparse_log_kernel_t(-60.0);
        assert_eq!(t.rows(), 32);
        assert_eq!(t.nnz(), k1.nnz());
        // Clones see the already-built truncation.
        let q = p.clone();
        assert!(Arc::ptr_eq(&q.sparse_log_kernel(-60.0), &k1));
        // A different θ is a different cache entry.
        let loose = p.sparse_log_kernel(f64::NEG_INFINITY);
        assert_eq!(loose.nnz(), 32 * 32);
    }

    #[test]
    fn absorbed_kernel_cache_is_keyed_by_tuning() {
        use crate::linalg::Stabilization;
        use std::sync::Arc;
        let p = ProblemSpec::new(24).with_eps(0.01).build(17);
        let stab = Stabilization::default();
        let k1 = p.absorbed_log_kernel(&stab);
        let k2 = p.absorbed_log_kernel(&stab);
        assert!(Arc::ptr_eq(&k1, &k2), "cache must return the same allocation");
        assert_eq!(k1.rows(), 24);
        assert_eq!(k1.theta(), stab.truncation_theta);
        assert_eq!(k1.covered(), stab.absorb_threshold);
        // Clones see the already-built truncation; the transpose is a
        // separate entry; a different τ is a different key.
        let q = p.clone();
        assert!(Arc::ptr_eq(&q.absorbed_log_kernel(&stab), &k1));
        let kt = p.absorbed_log_kernel_t(&stab);
        assert_eq!(kt.rows(), 24);
        let other = Stabilization { absorb_threshold: 5.0, ..stab };
        assert!(!Arc::ptr_eq(&p.absorbed_log_kernel(&other), &k1));
    }

    #[test]
    fn kernel_caches_are_shared_across_clones() {
        let p = ProblemSpec::new(16).build(2);
        let _ = p.kernel_t();
        let q = p.clone();
        // The clone sees the already-built transpose (same allocation).
        assert_eq!(p.kernel_t() as *const _, q.kernel_t() as *const _);
    }

    #[test]
    fn log_partition_slices_log_kernel() {
        use crate::linalg::Domain;
        let p = ProblemSpec::new(24).with_eps(0.01).build(13);
        let part = Partition::new_in(&p, 4, Domain::Log);
        assert_eq!(part.domain, Domain::Log);
        let lk = p.log_kernel();
        for (j, sh) in part.shards.iter().enumerate() {
            let m = 24 / 4;
            for i in 0..m {
                for col in 0..24 {
                    assert_eq!(sh.k_row[(i, col)], lk[(j * m + i, col)]);
                    assert_eq!(sh.k_col_t[(i, col)], lk[(col, j * m + i)]);
                }
            }
        }
    }

    #[test]
    fn paper_4x4_example_matches_text() {
        let p = Problem::paper_4x4(0.5);
        assert_eq!(p.n, 4);
        assert_eq!(p.a, vec![0.3, 0.2, 0.1, 0.4]);
        assert_eq!(p.cost[(0, 1)], 1.0);
        assert_eq!(p.cost[(3, 0)], 3.0);
        assert!((p.kernel()[(0, 0)] - 1.0).abs() < 1e-15); // exp(0)
    }
}
