//! Synthetic workload generation (paper §III–§IV) and problem slicing.
//!
//! Generates the `(a, b, C, K)` tuples the experiments consume:
//! * marginals `a, b` — Dirichlet simplex samples (strictly positive,
//!   summing to 1), or the paper's fixed 4-point example;
//! * cost families — the paper's circulant 4×4, squared-Euclidean on
//!   random supports, and random uniform costs;
//! * **off-diagonal block sparsity** `s ∈ {0, 0.5, 0.9, 1}` (§IV-D): a
//!   fraction `s` of the off-diagonal client-block pairs get their cost
//!   inflated so the Gibbs entries underflow toward 0;
//! * **condition classes** well/medium/ill (§IV-D) — the cost scale is
//!   chosen so `K = exp(−C/ε)` has benign, moderate or extreme dynamic
//!   range (its condition worsens as ε shrinks relative to cost spread);
//! * `N` target histograms (`b ∈ R^{n×N}`, Cuturi vectorization §IV-B3).
//!
//! [`Partition`] slices a problem across `c` clients exactly as the
//! paper's Fig. 1: client `j` owns `a_j, b_j`, row block `K_j` and the
//! transposed column block `K[:, j]ᵀ`.

mod generate;
mod partition;

pub use generate::{CondClass, Problem, ProblemSpec};
pub use partition::{ClientShard, Partition};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marginals_are_simplex_points() {
        let p = ProblemSpec::new(64).with_hists(3).build(7);
        assert!((p.a.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for h in 0..3 {
            let s: f64 = (0..64).map(|i| p.b[(i, h)]).sum();
            assert!((s - 1.0).abs() < 1e-12, "hist {h} sums to {s}");
        }
        assert!(p.a.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gibbs_kernel_positive_when_dense() {
        let p = ProblemSpec::new(32).build(1);
        assert!(p.k.as_slice().iter().all(|&x| x > 0.0));
    }

    #[test]
    fn sparsity_zeroes_offdiag_blocks() {
        let dense = ProblemSpec::new(64).with_sparsity(0.0, 4).build(3);
        let sparse = ProblemSpec::new(64).with_sparsity(1.0, 4).build(3);
        let count_small = |m: &crate::linalg::Mat| {
            m.as_slice().iter().filter(|&&x| x < 1e-100).count()
        };
        assert_eq!(count_small(&dense.k), 0);
        // s = 1: all 12 of 16 off-diagonal 16x16 blocks suppressed.
        assert_eq!(count_small(&sparse.k), 12 * 16 * 16);
    }

    #[test]
    fn condition_classes_order_dynamic_range() {
        let range = |c: CondClass| {
            let p = ProblemSpec::new(32).with_condition(c).build(5);
            let mx = p.k.as_slice().iter().cloned().fold(f64::MIN, f64::max);
            let mn = p.k.as_slice().iter().cloned().fold(f64::MAX, f64::min);
            mx / mn
        };
        let w = range(CondClass::Well);
        let m = range(CondClass::Medium);
        let i = range(CondClass::Ill);
        assert!(w < m && m < i, "ranges {w} {m} {i}");
    }

    #[test]
    fn partition_blocks_reassemble() {
        let p = ProblemSpec::new(24).with_hists(2).build(11);
        let part = Partition::new(&p, 4);
        assert_eq!(part.shards.len(), 4);
        for (j, sh) in part.shards.iter().enumerate() {
            let m = 24 / 4;
            assert_eq!(sh.k_row.rows(), m);
            assert_eq!(sh.k_col_t.rows(), m);
            // Row block matches the full kernel.
            for i in 0..m {
                for col in 0..24 {
                    assert_eq!(sh.k_row[(i, col)], p.k[(j * m + i, col)]);
                    // k_col_t[i][col] = K[col][j*m + i]
                    assert_eq!(sh.k_col_t[(i, col)], p.k[(col, j * m + i)]);
                }
            }
        }
    }

    #[test]
    fn paper_4x4_example_matches_text() {
        let p = Problem::paper_4x4(0.5);
        assert_eq!(p.n, 4);
        assert_eq!(p.a, vec![0.3, 0.2, 0.1, 0.4]);
        assert_eq!(p.cost[(0, 1)], 1.0);
        assert_eq!(p.cost[(3, 0)], 3.0);
        assert!((p.k[(0, 0)] - 1.0).abs() < 1e-15); // exp(0)
    }
}
