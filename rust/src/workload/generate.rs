//! Problem generator: marginals, cost families, sparsity, conditioning.

use crate::linalg::Mat;
use crate::rng::Rng;

/// Condition classes of the Gibbs kernel (paper §IV-D): the effective
/// conditioning of Sinkhorn is driven by `max C / ε` — we scale the cost
/// spread to produce benign → extreme dynamic ranges in `K`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CondClass {
    Well,
    Medium,
    Ill,
}

impl CondClass {
    /// Cost spread multiplier relative to ε.
    fn cost_scale(self, eps: f64) -> f64 {
        match self {
            CondClass::Well => 2.0 * eps,
            CondClass::Medium => 10.0 * eps,
            CondClass::Ill => 40.0 * eps,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "well" => Some(Self::Well),
            "medium" => Some(Self::Medium),
            "ill" => Some(Self::Ill),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CondClass::Well => "well",
            CondClass::Medium => "medium",
            CondClass::Ill => "ill",
        }
    }
}

/// Builder for synthetic problems.
#[derive(Clone, Debug)]
pub struct ProblemSpec {
    pub n: usize,
    pub hists: usize,
    pub eps: f64,
    /// Off-diagonal block sparsity `s` with the block grid it applies to.
    pub sparsity: f64,
    pub sparsity_blocks: usize,
    pub cond: CondClass,
}

impl ProblemSpec {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            hists: 1,
            eps: 0.05,
            sparsity: 0.0,
            sparsity_blocks: 4,
            cond: CondClass::Well,
        }
    }

    pub fn with_hists(mut self, nh: usize) -> Self {
        self.hists = nh;
        self
    }

    pub fn with_eps(mut self, eps: f64) -> Self {
        self.eps = eps;
        self
    }

    pub fn with_sparsity(mut self, s: f64, blocks: usize) -> Self {
        self.sparsity = s;
        self.sparsity_blocks = blocks;
        self
    }

    pub fn with_condition(mut self, c: CondClass) -> Self {
        self.cond = c;
        self
    }

    /// Generate the problem deterministically from `seed`.
    pub fn build(&self, seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let n = self.n;
        let a = rng.dirichlet(n, 1.0);
        let mut b = Mat::zeros(n, self.hists);
        for h in 0..self.hists {
            let col = rng.dirichlet(n, 1.0);
            for i in 0..n {
                b[(i, h)] = col[i];
            }
        }

        // Squared-Euclidean cost on random 1-D supports, normalized to
        // [0, scale]; the paper's §V cost family.
        let scale = self.cond.cost_scale(self.eps);
        let mut xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        xs.sort_by(|p, q| p.partial_cmp(q).unwrap());
        let mut cost = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let d = xs[i] - xs[j];
                cost[(i, j)] = scale * d * d;
            }
        }

        // Off-diagonal block sparsity: fraction `s` of off-diagonal
        // (bi, bj) client-block pairs get their cost pushed so high the
        // Gibbs entry underflows — the "sparse kernel" regime of §IV-D.
        // The pattern is symmetric (kill (i,j) with (j,i)) and the
        // diagonal blocks always survive; `b` is then rebalanced so each
        // diagonal block carries the same mass in both marginals, which
        // keeps the problem feasible at every s (at s = 1 the plan must
        // be block-diagonal, so mismatched block masses would make the
        // marginal constraints unsatisfiable — the paper's grids
        // converge at s = 1, so theirs are feasible by construction).
        if self.sparsity > 0.0 && self.sparsity_blocks > 1 && n % self.sparsity_blocks == 0
        {
            let nb = self.sparsity_blocks;
            let m = n / nb;
            let mut offdiag: Vec<(usize, usize)> = (0..nb)
                .flat_map(|i| (i + 1..nb).map(move |j| (i, j)))
                .collect();
            rng.shuffle(&mut offdiag);
            let kill = ((offdiag.len() as f64) * self.sparsity).round() as usize;
            let huge = 800.0 * self.eps; // exp(-800) == 0 in f64
            for &(bi, bj) in offdiag.iter().take(kill) {
                for (pi, pj) in [(bi, bj), (bj, bi)] {
                    for i in pi * m..(pi + 1) * m {
                        for j in pj * m..(pj + 1) * m {
                            cost[(i, j)] = huge;
                        }
                    }
                }
            }
            // Feasibility rebalance: per diagonal block, scale every b
            // column so the block mass equals a's block mass (column
            // sums stay 1 since the a-block masses sum to 1).
            for blk in 0..nb {
                let (r0, r1) = (blk * m, (blk + 1) * m);
                let a_mass: f64 = a[r0..r1].iter().sum();
                for h in 0..self.hists {
                    let b_mass: f64 = (r0..r1).map(|i| b[(i, h)]).sum();
                    if b_mass > 0.0 {
                        let scale = a_mass / b_mass;
                        for i in r0..r1 {
                            b[(i, h)] *= scale;
                        }
                    }
                }
            }
        }

        let k = cost.map(|c| (-c / self.eps).exp());
        Problem { n, eps: self.eps, a, b, cost, k }
    }
}

/// A concrete entropic-OT instance.
#[derive(Clone, Debug)]
pub struct Problem {
    pub n: usize,
    pub eps: f64,
    /// Source marginal, length `n`.
    pub a: Vec<f64>,
    /// Target marginal(s), `n × N`.
    pub b: Mat,
    /// Cost matrix `C`.
    pub cost: Mat,
    /// Gibbs kernel `K = exp(−C/ε)`.
    pub k: Mat,
}

impl Problem {
    /// Number of simultaneous target histograms.
    pub fn hists(&self) -> usize {
        self.b.cols()
    }

    /// The paper's §III worked example: a = [.3 .2 .1 .4],
    /// b = [.2 .3 .3 .2], circulant cost.
    pub fn paper_4x4(eps: f64) -> Problem {
        let a = vec![0.3, 0.2, 0.1, 0.4];
        let b_col = [0.2, 0.3, 0.3, 0.2];
        let mut b = Mat::zeros(4, 1);
        for i in 0..4 {
            b[(i, 0)] = b_col[i];
        }
        let cost = Mat::from_vec(
            4,
            4,
            vec![
                0.0, 1.0, 2.0, 3.0, //
                1.0, 0.0, 3.0, 2.0, //
                2.0, 3.0, 0.0, 1.0, //
                3.0, 2.0, 1.0, 0.0,
            ],
        );
        let k = cost.map(|c| (-c / eps).exp());
        Problem { n: 4, eps, a, b, cost, k }
    }

    /// Build a problem from explicit pieces (finance pipeline).
    pub fn from_parts(a: Vec<f64>, b: Mat, cost: Mat, eps: f64) -> Problem {
        let n = a.len();
        assert_eq!(b.rows(), n);
        assert_eq!(cost.rows(), n);
        assert_eq!(cost.cols(), n);
        let k = cost.map(|c| (-c / eps).exp());
        Problem { n, eps, a, b, cost, k }
    }
}
