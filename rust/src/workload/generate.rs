//! Problem generator: marginals, cost families, sparsity, conditioning.

use crate::linalg::{AbsorbedLogCsr, Domain, LogCsr, Mat, Stabilization};
use crate::rng::Rng;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Condition classes of the Gibbs kernel (paper §IV-D): the effective
/// conditioning of Sinkhorn is driven by `max C / ε` — we scale the cost
/// spread to produce benign → extreme dynamic ranges in `K`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CondClass {
    Well,
    Medium,
    Ill,
}

impl CondClass {
    /// Cost spread multiplier relative to ε.
    fn cost_scale(self, eps: f64) -> f64 {
        match self {
            CondClass::Well => 2.0 * eps,
            CondClass::Medium => 10.0 * eps,
            CondClass::Ill => 40.0 * eps,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "well" => Some(Self::Well),
            "medium" => Some(Self::Medium),
            "ill" => Some(Self::Ill),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CondClass::Well => "well",
            CondClass::Medium => "medium",
            CondClass::Ill => "ill",
        }
    }
}

/// Builder for synthetic problems.
#[derive(Clone, Debug)]
pub struct ProblemSpec {
    pub n: usize,
    pub hists: usize,
    pub eps: f64,
    /// Off-diagonal block sparsity `s` with the block grid it applies to.
    pub sparsity: f64,
    pub sparsity_blocks: usize,
    pub cond: CondClass,
}

impl ProblemSpec {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            hists: 1,
            eps: 0.05,
            sparsity: 0.0,
            sparsity_blocks: 4,
            cond: CondClass::Well,
        }
    }

    pub fn with_hists(mut self, nh: usize) -> Self {
        self.hists = nh;
        self
    }

    pub fn with_eps(mut self, eps: f64) -> Self {
        self.eps = eps;
        self
    }

    pub fn with_sparsity(mut self, s: f64, blocks: usize) -> Self {
        self.sparsity = s;
        self.sparsity_blocks = blocks;
        self
    }

    pub fn with_condition(mut self, c: CondClass) -> Self {
        self.cond = c;
        self
    }

    /// Generate the problem deterministically from `seed`.
    pub fn build(&self, seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let n = self.n;
        let a = rng.dirichlet(n, 1.0);
        let mut b = Mat::zeros(n, self.hists);
        for h in 0..self.hists {
            let col = rng.dirichlet(n, 1.0);
            for i in 0..n {
                b[(i, h)] = col[i];
            }
        }

        // Squared-Euclidean cost on random 1-D supports, normalized to
        // [0, scale]; the paper's §V cost family.
        let scale = self.cond.cost_scale(self.eps);
        let mut xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        xs.sort_by(|p, q| p.partial_cmp(q).unwrap());
        let mut cost = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let d = xs[i] - xs[j];
                cost[(i, j)] = scale * d * d;
            }
        }

        let mut masked_cost_min = None;
        // Off-diagonal block sparsity: fraction `s` of off-diagonal
        // (bi, bj) client-block pairs get their cost pushed so high the
        // Gibbs entry underflows — the "sparse kernel" regime of §IV-D.
        // The pattern is symmetric (kill (i,j) with (j,i)) and the
        // diagonal blocks always survive; `b` is then rebalanced so each
        // diagonal block carries the same mass in both marginals, which
        // keeps the problem feasible at every s (at s = 1 the plan must
        // be block-diagonal, so mismatched block masses would make the
        // marginal constraints unsatisfiable — the paper's grids
        // converge at s = 1, so theirs are feasible by construction).
        if self.sparsity > 0.0 && self.sparsity_blocks > 1 && n % self.sparsity_blocks == 0
        {
            let nb = self.sparsity_blocks;
            let m = n / nb;
            let mut offdiag: Vec<(usize, usize)> = (0..nb)
                .flat_map(|i| (i + 1..nb).map(move |j| (i, j)))
                .collect();
            rng.shuffle(&mut offdiag);
            let kill = ((offdiag.len() as f64) * self.sparsity).round() as usize;
            // exp(-800) == 0 in f64 — the deliberate "kernel zero" mark.
            // Recorded on the problem (`masked_cost_min`) so domain auto-
            // selection can tell intentional zeros from underflow.
            let huge = 800.0 * self.eps;
            masked_cost_min = Some(huge);
            for &(bi, bj) in offdiag.iter().take(kill) {
                for (pi, pj) in [(bi, bj), (bj, bi)] {
                    for i in pi * m..(pi + 1) * m {
                        for j in pj * m..(pj + 1) * m {
                            cost[(i, j)] = huge;
                        }
                    }
                }
            }
            // Feasibility rebalance: per diagonal block, scale every b
            // column so the block mass equals a's block mass (column
            // sums stay 1 since the a-block masses sum to 1).
            for blk in 0..nb {
                let (r0, r1) = (blk * m, (blk + 1) * m);
                let a_mass: f64 = a[r0..r1].iter().sum();
                for h in 0..self.hists {
                    let b_mass: f64 = (r0..r1).map(|i| b[(i, h)]).sum();
                    if b_mass > 0.0 {
                        let scale = a_mass / b_mass;
                        for i in r0..r1 {
                            b[(i, h)] *= scale;
                        }
                    }
                }
            }
        }

        let mut p = Problem::from_parts(a, b, cost, self.eps);
        p.masked_cost_min = masked_cost_min;
        p
    }
}

/// A concrete entropic-OT instance.
///
/// The *cost matrix* is the source of truth; the Gibbs kernel
/// `K = exp(−C/ε)`, its log-domain twin `log K = −C/ε`, both
/// transposes, and the θ-truncated sparse log kernels are materialized
/// lazily and cached (shared across clones via `Arc`). A small-ε spec
/// therefore never builds an all-zero linear kernel unless a
/// linear-domain solver actually asks for one, and multi-solve
/// experiments pay each O(n²) transpose/truncation exactly once.
#[derive(Clone, Debug)]
pub struct Problem {
    pub n: usize,
    pub eps: f64,
    /// Source marginal, length `n`.
    pub a: Vec<f64>,
    /// Target marginal(s), `n × N`.
    pub b: Mat,
    /// Cost matrix `C`.
    pub cost: Mat,
    /// Cost level at/above which entries are *deliberate* kernel zeros
    /// (the §IV-D block-sparsification sentinel). Such entries are meant
    /// to underflow and must not push domain auto-selection into the log
    /// path; `None` means every entry is genuine.
    pub masked_cost_min: Option<f64>,
    kernel: Arc<OnceLock<Mat>>,
    kernel_t: Arc<OnceLock<Mat>>,
    log_kernel: Arc<OnceLock<Mat>>,
    log_kernel_t: Arc<OnceLock<Mat>>,
    /// Truncated sparse log kernels and their transposes, keyed by the
    /// truncation threshold θ (bit pattern — θ values come from a single
    /// config knob, so the map stays tiny). Shared across clones like
    /// the dense caches.
    sparse_log: Arc<Mutex<BTreeMap<u64, Arc<LogCsr>>>>,
    sparse_log_t: Arc<Mutex<BTreeMap<u64, Arc<LogCsr>>>>,
    /// Zero-reference absorbed kernels for the hybrid schedule, keyed by
    /// the (θ, τ) tuning pair (bit patterns — both come from config
    /// knobs). Hybrid operators start from the shared support and
    /// copy-on-write at their first re-absorption, so multi-solve
    /// experiments pay each initial truncation exactly once.
    absorbed_log: Arc<Mutex<BTreeMap<(u64, u64), Arc<AbsorbedLogCsr>>>>,
    absorbed_log_t: Arc<Mutex<BTreeMap<(u64, u64), Arc<AbsorbedLogCsr>>>>,
}

impl Problem {
    /// Number of simultaneous target histograms.
    pub fn hists(&self) -> usize {
        self.b.cols()
    }

    /// Gibbs kernel `K = exp(−C/ε)` (built on first use, then cached).
    pub fn kernel(&self) -> &Mat {
        self.kernel.get_or_init(|| {
            let eps = self.eps;
            self.cost.map(|c| (-c / eps).exp())
        })
    }

    /// Cached transpose `Kᵀ` — the v-update operator's matrix.
    pub fn kernel_t(&self) -> &Mat {
        self.kernel_t.get_or_init(|| self.kernel().transpose())
    }

    /// Log-domain kernel `log K = −C/ε` (no exp, no underflow).
    pub fn log_kernel(&self) -> &Mat {
        self.log_kernel.get_or_init(|| {
            let eps = self.eps;
            self.cost.map(|c| -c / eps)
        })
    }

    /// Cached transpose `(log K)ᵀ`.
    pub fn log_kernel_t(&self) -> &Mat {
        self.log_kernel_t.get_or_init(|| self.log_kernel().transpose())
    }

    /// Truncated sparse log kernel at threshold `theta` (built on first
    /// use, then cached and shared across clones — multi-solve
    /// experiments truncate exactly once per θ).
    pub fn sparse_log_kernel(&self, theta: f64) -> Arc<LogCsr> {
        let mut cache = self.sparse_log.lock().expect("sparse log cache");
        cache
            .entry(theta.to_bits())
            .or_insert_with(|| Arc::new(LogCsr::from_dense_log(self.log_kernel(), theta)))
            .clone()
    }

    /// Cached truncated transpose. Truncation is row-relative, so this
    /// is built from the (cached) dense transpose rather than by
    /// transposing the truncated kernel: each operator drops entries
    /// relative to *its own* logsumexp axis.
    pub fn sparse_log_kernel_t(&self, theta: f64) -> Arc<LogCsr> {
        let mut cache = self.sparse_log_t.lock().expect("sparse log-t cache");
        cache
            .entry(theta.to_bits())
            .or_insert_with(|| Arc::new(LogCsr::from_dense_log(self.log_kernel_t(), theta)))
            .clone()
    }

    /// Density report for the truncated log kernel at `theta` — the
    /// number the runtime's sparse dispatch cutoff is compared against.
    pub fn sparse_log_density(&self, theta: f64) -> f64 {
        self.sparse_log_kernel(theta).density()
    }

    /// Zero-reference absorbed kernel for the hybrid schedule at the
    /// given (θ, τ) tuning (built on first use, then cached and shared
    /// across clones). Seeding hybrid operators from here keeps the
    /// initial `O(n²)` truncation to one per (problem, tuning) pair.
    pub fn absorbed_log_kernel(&self, stab: &Stabilization) -> Arc<AbsorbedLogCsr> {
        Self::absorbed_entry(&self.absorbed_log, self.log_kernel(), stab)
    }

    /// Cached zero-reference absorbed transpose (the v-update seed).
    /// Built from the dense transpose, not by transposing the absorbed
    /// kernel: absorption shifts rows relative to *its own* product
    /// axis.
    pub fn absorbed_log_kernel_t(&self, stab: &Stabilization) -> Arc<AbsorbedLogCsr> {
        Self::absorbed_entry(&self.absorbed_log_t, self.log_kernel_t(), stab)
    }

    fn absorbed_entry(
        cache: &Mutex<BTreeMap<(u64, u64), Arc<AbsorbedLogCsr>>>,
        a_log: &Mat,
        stab: &Stabilization,
    ) -> Arc<AbsorbedLogCsr> {
        let key = (stab.truncation_theta.to_bits(), stab.absorb_threshold.to_bits());
        let mut cache = cache.lock().expect("absorbed log cache");
        cache
            .entry(key)
            .or_insert_with(|| {
                let tau = stab.absorb_threshold;
                Arc::new(AbsorbedLogCsr::from_dense_log(
                    a_log,
                    &vec![0.0; a_log.cols()],
                    stab.truncation_theta,
                    tau,
                    tau,
                ))
            })
            .clone()
    }

    /// The kernel in the representation `domain` expects.
    pub fn kernel_for(&self, domain: Domain) -> &Mat {
        match domain {
            Domain::Linear => self.kernel(),
            Domain::Log => self.log_kernel(),
        }
    }

    /// The transposed kernel in the representation `domain` expects.
    pub fn kernel_t_for(&self, domain: Domain) -> &Mat {
        match domain {
            Domain::Linear => self.kernel_t(),
            Domain::Log => self.log_kernel_t(),
        }
    }

    /// Largest *genuine* cost entry — `cost_max() / eps` is the exponent
    /// dynamic range that decides when the linear kernel underflows f64.
    /// Entries at/above the sparsification sentinel (`masked_cost_min`)
    /// are deliberate kernel zeros and excluded, so sparse workloads do
    /// not spuriously auto-select the log domain.
    pub fn cost_max(&self) -> f64 {
        let cap = self.masked_cost_min.unwrap_or(f64::INFINITY);
        self.cost
            .as_slice()
            .iter()
            .cloned()
            .filter(|&c| c < cap)
            .fold(0.0, f64::max)
    }

    /// The paper's §III worked example: a = [.3 .2 .1 .4],
    /// b = [.2 .3 .3 .2], circulant cost.
    pub fn paper_4x4(eps: f64) -> Problem {
        let a = vec![0.3, 0.2, 0.1, 0.4];
        let b_col = [0.2, 0.3, 0.3, 0.2];
        let mut b = Mat::zeros(4, 1);
        for i in 0..4 {
            b[(i, 0)] = b_col[i];
        }
        let cost = Mat::from_vec(
            4,
            4,
            vec![
                0.0, 1.0, 2.0, 3.0, //
                1.0, 0.0, 3.0, 2.0, //
                2.0, 3.0, 0.0, 1.0, //
                3.0, 2.0, 1.0, 0.0,
            ],
        );
        Problem::from_parts(a, b, cost, eps)
    }

    /// Build a problem from explicit pieces (finance pipeline). Kernels
    /// are not materialized here — they build lazily on first access.
    pub fn from_parts(a: Vec<f64>, b: Mat, cost: Mat, eps: f64) -> Problem {
        let n = a.len();
        assert_eq!(b.rows(), n);
        assert_eq!(cost.rows(), n);
        assert_eq!(cost.cols(), n);
        Problem {
            n,
            eps,
            a,
            b,
            cost,
            masked_cost_min: None,
            kernel: Arc::new(OnceLock::new()),
            kernel_t: Arc::new(OnceLock::new()),
            log_kernel: Arc::new(OnceLock::new()),
            log_kernel_t: Arc::new(OnceLock::new()),
            sparse_log: Arc::new(Mutex::new(BTreeMap::new())),
            sparse_log_t: Arc::new(Mutex::new(BTreeMap::new())),
            absorbed_log: Arc::new(Mutex::new(BTreeMap::new())),
            absorbed_log_t: Arc::new(Mutex::new(BTreeMap::new())),
        }
    }
}
