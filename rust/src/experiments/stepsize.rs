//! Table I + Figs 10–12 — the damping step size α in the asynchronous
//! federation: time-to-convergence across α × node counts (CPU-speed
//! backend, like the paper's §IV-C2), plus repeated-run variability.

use super::{dump_json, Scale};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::metrics::Summary;
use crate::net::LatencyModel;
use crate::sinkhorn::StopPolicy;
use crate::workload::ProblemSpec;

pub struct StepsizeArgs {
    pub n: usize,
    pub alphas: Vec<f64>,
    pub nodes: Vec<usize>,
    pub repeats: usize,
    pub threshold: f64,
    pub max_iters: usize,
    pub backend: BackendKind,
    pub out: Option<String>,
}

impl StepsizeArgs {
    pub fn at_scale(scale: Scale) -> Self {
        Self {
            n: scale.sizes()[scale.sizes().len() / 2],
            alphas: vec![0.1, 0.25, 0.5],
            nodes: match scale {
                Scale::Quick => vec![2],
                _ => vec![2, 4, 8],
            },
            repeats: scale.repeats(),
            threshold: 1e-10,
            max_iters: 8000,
            backend: BackendKind::Native, // paper runs this study on CPU
            out: None,
        }
    }
}

pub fn run(args: &StepsizeArgs) -> anyhow::Result<Json> {
    println!(
        "# Table I: async time-to-convergence (s) vs α × nodes, n={}, {} repeats",
        args.n, args.repeats
    );
    let p = ProblemSpec::new(args.n).with_eps(0.05).build(55);
    let policy = StopPolicy {
        threshold: args.threshold,
        max_iters: args.max_iters,
        check_every: 5,
        ..Default::default()
    };

    print!("{:>8}", "nodes");
    for a in &args.alphas {
        print!(" {:>14}", format!("α={a}"));
    }
    println!();

    let mut rows = Vec::new();
    for &c in &args.nodes {
        if args.n % c != 0 {
            continue;
        }
        print!("{c:>8}");
        let mut cells = Vec::new();
        for &alpha in &args.alphas {
            let mut times = Vec::new();
            let mut conv = 0usize;
            for r in 0..args.repeats {
                let cfg = SolveConfig {
                    variant: Variant::AsyncA2A,
                    backend: args.backend,
                    clients: c,
                    alpha,
                    net: LatencyModel::lan(),
                    seed: 7000 + r as u64,
                    ..Default::default()
                };
                let out = run_federated(&p, &cfg, policy, false);
                if out.converged {
                    conv += 1;
                    times.push(out.secs);
                }
            }
            let s = Summary::of(&times);
            let cell = if times.is_empty() {
                "   (no conv)".to_string()
            } else {
                format!("{:>10.2}", s.mean)
            };
            print!(" {cell:>14}");
            cells.push(Json::obj(vec![
                ("alpha", alpha.into()),
                ("mean_secs", s.mean.into()),
                ("std_secs", s.std.into()),
                ("converged", conv.into()),
                ("repeats", args.repeats.into()),
            ]));
        }
        println!();
        rows.push(Json::obj(vec![("nodes", c.into()), ("cells", Json::Arr(cells))]));
    }

    let doc = Json::obj(vec![
        ("experiment", "stepsize".into()),
        ("n", args.n.into()),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
