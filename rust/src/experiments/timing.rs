//! Figs 6/14 (GPU-speed) and 18/23/24 (CPU-speed) — computation vs
//! communication time per node at a fixed iteration budget.
//!
//! The paper fixes 250 iterations at n = 10000 and plots per-node comp
//! and comm times against the node count, showing comm dominating at
//! GPU-speed compute and the balance flipping at CPU speed (§IV-E). Our
//! "GPU" is the XLA backend, our "CPU" the (serial) native backend.

use super::{dump_json, Scale};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::metrics::Summary;
use crate::net::{LatencyModel, WireFormat};
use crate::sinkhorn::StopPolicy;
use crate::workload::ProblemSpec;

pub struct TimingArgs {
    pub variant: Variant,
    pub backend: BackendKind,
    pub n: usize,
    pub iters: usize,
    pub nodes: Vec<usize>,
    pub net: LatencyModel,
    /// Repeats for the per-node distribution plots (Figs 23–24).
    pub repeats: usize,
    /// Wire codec (`--wire-format`) — the comm columns then measure the
    /// encoded-frame exchange, and the emitted rows carry the per-kind
    /// byte buckets so the compression factor is visible.
    pub wire: WireFormat,
    /// Slice-streaming exchange (`--stream-exchange`).
    pub stream_exchange: bool,
    /// DeltaF32 keyframe cadence (`--wire-keyframe-every`).
    pub wire_keyframe_every: usize,
    pub out: Option<String>,
}

impl TimingArgs {
    pub fn at_scale(scale: Scale) -> Self {
        Self {
            variant: Variant::SyncA2A,
            backend: BackendKind::Xla,
            n: *scale.sizes().last().unwrap(),
            iters: match scale {
                Scale::Quick => 25,
                _ => 250,
            },
            nodes: scale.node_counts(),
            net: LatencyModel::lan(),
            repeats: match scale {
                Scale::Quick => 1,
                _ => 3,
            },
            wire: WireFormat::F64,
            stream_exchange: false,
            wire_keyframe_every: 0,
            out: None,
        }
    }
}

pub fn run(args: &TimingArgs) -> anyhow::Result<Json> {
    // Fixed iteration budget: threshold 0 disables convergence stops.
    let policy = StopPolicy {
        threshold: 0.0,
        max_iters: args.iters,
        check_every: args.iters + 1, // no mid-run checks
        ..Default::default()
    };
    let p = ProblemSpec::new(args.n).with_eps(0.05).build(77);

    println!(
        "# Figs 6/14/18: comp vs comm per node, n={}, {} iterations, backend={}, variant={}, wire={}{}",
        args.n,
        args.iters,
        args.backend.name(),
        args.variant.name(),
        args.wire.name(),
        if args.stream_exchange { " (streamed)" } else { "" }
    );
    println!(
        "{:>6} {:>4} {:>12} {:>12} {:>12}  (per-node; slowest node shown, mean of {} runs)",
        "nodes", "rep", "comp (s)", "comm (s)", "total (s)", args.repeats
    );

    let mut rows = Vec::new();
    for &c in &args.nodes {
        if args.n % c != 0 {
            continue;
        }
        let variant = if c == 1 { Variant::Centralized } else { args.variant };
        let mut comps = Vec::new();
        let mut comms = Vec::new();
        let mut node_rows = Vec::new();
        let mut wire_bytes: u64 = 0;
        let mut wire_by_kind: Vec<Json> = Vec::new();
        for rep in 0..args.repeats {
            let cfg = SolveConfig {
                variant,
                backend: args.backend,
                clients: c,
                net: args.net,
                seed: 1000 + rep as u64,
                wire: args.wire,
                stream_exchange: args.stream_exchange,
                wire_keyframe_every: args.wire_keyframe_every,
                ..Default::default()
            };
            let out = run_federated(&p, &cfg, policy, false);
            // One rep's snapshot per row: sync fixed-budget runs move
            // identical byte totals every rep; async reps can differ
            // (server relay passes are schedule-dependent), so treat
            // the async byte columns as representative, not exact.
            wire_bytes = out.traffic.total_bytes;
            wire_by_kind = out
                .traffic
                .by_kind
                .iter()
                .map(|&(name, bytes, msgs)| {
                    Json::obj(vec![
                        ("kind", name.into()),
                        ("bytes", bytes.into()),
                        ("msgs", msgs.into()),
                    ])
                })
                .collect();
            for s in &out.node_stats {
                node_rows.push(Json::obj(vec![
                    ("nodes", c.into()),
                    ("rep", rep.into()),
                    ("node", s.id.into()),
                    ("role", s.role.into()),
                    ("comp_secs", s.comp_secs().into()),
                    ("comm_secs", s.comm_secs().into()),
                ]));
            }
            let slow = crate::coordinator::slowest_node(&out.node_stats);
            comps.push(slow.comp_secs());
            comms.push(slow.comm_secs());
            println!(
                "{:>6} {:>4} {:>12.3} {:>12.3} {:>12.3}",
                c,
                rep,
                slow.comp_secs(),
                slow.comm_secs(),
                slow.total_secs()
            );
        }
        let sc = Summary::of(&comps);
        let sm = Summary::of(&comms);
        rows.push(Json::obj(vec![
            ("nodes", c.into()),
            ("comp_mean", sc.mean.into()),
            ("comp_std", sc.std.into()),
            ("comm_mean", sm.mean.into()),
            ("comm_std", sm.std.into()),
            ("wire_bytes", wire_bytes.into()),
            ("beta_secs", args.net.beta_secs(wire_bytes).into()),
            ("wire_by_kind", Json::Arr(wire_by_kind)),
            ("per_node", Json::Arr(node_rows)),
        ]));
    }

    let doc = Json::obj(vec![
        ("experiment", "timing".into()),
        ("variant", args.variant.name().into()),
        ("backend", args.backend.name().into()),
        ("wire_format", args.wire.name().into()),
        ("stream_exchange", args.stream_exchange.into()),
        ("n", args.n.into()),
        ("iters", args.iters.into()),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
