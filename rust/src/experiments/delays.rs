//! Figs 15–17 + Table V — staleness (τ) distribution of the async
//! federation: KDE-style binned densities of τ for small and large τ,
//! and the per-node-count max/min/mean/std statistics.

use super::{dump_json, Scale};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::metrics::{Histogram, Summary};
use crate::net::LatencyModel;
use crate::sinkhorn::StopPolicy;
use crate::workload::ProblemSpec;

pub struct DelaysArgs {
    pub n: usize,
    pub nodes: Vec<usize>,
    pub iters: usize,
    pub sims: usize,
    pub backend: BackendKind,
    pub net: LatencyModel,
    pub out: Option<String>,
}

impl DelaysArgs {
    pub fn at_scale(scale: Scale) -> Self {
        Self {
            n: scale.sizes()[scale.sizes().len() / 2],
            nodes: match scale {
                Scale::Quick => vec![2],
                _ => vec![2, 4, 8],
            },
            iters: 500,
            sims: match scale {
                Scale::Quick => 3,
                Scale::Default => 20,
                Scale::Paper => 1000,
            },
            backend: BackendKind::Xla,
            net: LatencyModel::lan(),
            out: None,
        }
    }
}

pub fn run(args: &DelaysArgs) -> anyhow::Result<Json> {
    println!(
        "# Figs 15-17 + Table V: τ staleness study, n={}, T={}, {} sims",
        args.n, args.iters, args.sims
    );
    println!(
        "{:>6} {:>10} {:>8} {:>8} {:>10} {:>10}",
        "nodes", "samples", "τ_max", "τ_min", "τ_mean", "τ_std"
    );
    let policy = StopPolicy {
        threshold: 0.0, // fixed T iterations, like the paper
        max_iters: args.iters,
        check_every: args.iters + 1,
        ..Default::default()
    };

    let mut rows = Vec::new();
    for &c in &args.nodes {
        if args.n % c != 0 {
            continue;
        }
        let mut taus: Vec<f64> = Vec::new();
        for s in 0..args.sims {
            let p = ProblemSpec::new(args.n).with_eps(0.05).build(600 + s as u64);
            let cfg = SolveConfig {
                variant: Variant::AsyncA2A,
                backend: args.backend,
                clients: c,
                alpha: 0.5,
                net: args.net,
                seed: 600 + s as u64,
                ..Default::default()
            };
            let out = run_federated(&p, &cfg, policy, false);
            taus.extend(out.taus.iter().map(|&t| t as f64));
        }
        // The paper plots only τ ≥ 1 (0 would mean no delay).
        let nonzero: Vec<f64> = taus.iter().cloned().filter(|&t| t >= 1.0).collect();
        let s = Summary::of(&nonzero);
        println!(
            "{:>6} {:>10} {:>8} {:>8} {:>10.2} {:>10.2}",
            c, nonzero.len(), s.max, s.min, s.mean, s.std
        );
        // Fig 16: density for τ ∈ [1, 50]; Fig 17: tail τ > 50.
        let head: Vec<f64> = nonzero.iter().cloned().filter(|&t| t <= 50.0).collect();
        let tail: Vec<f64> = nonzero.iter().cloned().filter(|&t| t > 50.0).collect();
        let hist_head = Histogram::of(&head, 25);
        let hist_tail = if tail.is_empty() { None } else { Some(Histogram::of(&tail, 25)) };
        rows.push(Json::obj(vec![
            ("nodes", c.into()),
            ("samples", nonzero.len().into()),
            ("tau_max", s.max.into()),
            ("tau_min", s.min.into()),
            ("tau_mean", s.mean.into()),
            ("tau_std", s.std.into()),
            (
                "kde_head",
                Json::obj(vec![
                    ("centers", Json::nums(&hist_head.centers())),
                    ("density", Json::nums(&hist_head.density())),
                ]),
            ),
            (
                "kde_tail",
                match hist_tail {
                    Some(h) => Json::obj(vec![
                        ("centers", Json::nums(&h.centers())),
                        ("density", Json::nums(&h.density())),
                    ]),
                    None => Json::Null,
                },
            ),
        ]));
    }

    let doc = Json::obj(vec![
        ("experiment", "delays".into()),
        ("n", args.n.into()),
        ("iters", args.iters.into()),
        ("sims", args.sims.into()),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
