//! Appendix B performance grids (Tables VII–XXXVI) + the χ² analysis of
//! Table VI.
//!
//! Grid: n × sparsity s × N histograms × condition class, for each
//! variant (centralized / sync-a2a / sync-star / async-a2a, plus the
//! decentralized ring and gossip topologies) × node count. Each row
//! reports comp/comm/total seconds of the slowest node, iterations to
//! convergence, and (async) whether it converged — the exact columns of
//! the paper's appendix tables, plus a `topology` column grouping the
//! per-topology comm terms (a2a / star / ring / gossip pay different
//! α–β mixes for the same solve).

use super::{build_problem, dump_json, run_case_cfg, Scale};
use crate::config::{BackendKind, DomainChoice, ExchangeMode, SolveConfig, Variant};
use crate::jsonio::Json;
use crate::linalg::Stabilization;
use crate::metrics::{chi2_sf, chi2_stat, RunRecord};
use crate::net::{LatencyModel, WireFormat};
use crate::runtime::GreedySpec;
use crate::sinkhorn::StopPolicy;
use crate::workload::CondClass;

pub struct PerfGridArgs {
    pub variants: Vec<Variant>,
    pub sizes: Vec<usize>,
    pub sparsities: Vec<f64>,
    pub hists: Vec<usize>,
    pub conds: Vec<CondClass>,
    pub nodes: Vec<usize>,
    pub threshold: f64,
    pub max_iters: usize,
    pub backend: BackendKind,
    pub net: LatencyModel,
    pub alpha_async: f64,
    pub chi2: bool,
    /// Add the per-node vs fleet-synchronized absorption comparison
    /// (`--fleet-compare`): each federated variant on a small-ε
    /// log-domain workload, with and without the coordinator-broadcast
    /// re-absorption protocol, reporting both retruncation totals.
    pub fleet_compare: bool,
    /// Wire codec for the coded streams (`--wire-format`): rows report
    /// per-iteration comm time and the per-kind byte buckets on the
    /// *encoded* frames, so an `f32` grid against an `f64` grid shows
    /// the β term halving directly.
    pub wire: WireFormat,
    /// Slice-streaming exchange (`--stream-exchange`) for the sync
    /// variants.
    pub stream_exchange: bool,
    /// DeltaF32 keyframe cadence (`--wire-keyframe-every`).
    pub wire_keyframe_every: usize,
    /// Exchange schedule (`--exchange`): `full` dense slices, or
    /// `greedy` top-k violation rows as sparse coordinate frames. Rows
    /// report per-iteration exchanged bytes and the violation-mass
    /// share the selected rows covered, so a greedy grid against a full
    /// grid shows the α–β uplink saving directly.
    pub exchange: ExchangeMode,
    /// Greedy row budget (`--greedy-topk`), unused under `full`.
    pub greedy_topk: GreedySpec,
    pub out: Option<String>,
}

impl PerfGridArgs {
    pub fn at_scale(scale: Scale) -> Self {
        let (sizes, hists) = match scale {
            Scale::Quick => (vec![64], vec![1, 8]),
            Scale::Default => (vec![256, 512, 1024], vec![1, 64]),
            Scale::Paper => (vec![1000, 5000, 10000], vec![1, 100, 1000, 10000]),
        };
        Self {
            variants: vec![
                Variant::Centralized,
                Variant::SyncA2A,
                Variant::SyncStar,
                Variant::AsyncA2A,
                Variant::Ring,
                Variant::Gossip,
            ],
            sizes,
            sparsities: vec![0.0, 0.5, 0.9, 1.0],
            hists,
            conds: vec![CondClass::Well, CondClass::Medium, CondClass::Ill],
            nodes: match scale {
                Scale::Quick => vec![2],
                _ => vec![2, 4, 8],
            },
            // The paper's appendix uses threshold 1e-15 with instances
            // that converge in 3-5 iterations; our condition classes
            // stress the solver harder, so the default threshold is
            // 1e-10 (the shape signal — iterations vs s/cond/N — is
            // unchanged, see EXPERIMENTS.md).
            threshold: 1e-10,
            max_iters: 1500,
            backend: BackendKind::Xla,
            net: LatencyModel::lan(),
            alpha_async: 0.5,
            chi2: false,
            fleet_compare: false,
            wire: WireFormat::F64,
            stream_exchange: false,
            wire_keyframe_every: 0,
            exchange: ExchangeMode::Full,
            greedy_topk: GreedySpec::MassFraction(0.5),
            out: None,
        }
    }
}

pub fn run(args: &PerfGridArgs) -> anyhow::Result<Json> {
    let policy = StopPolicy {
        threshold: args.threshold,
        max_iters: args.max_iters,
        check_every: 1,
        ..Default::default()
    };

    let mut records: Vec<RunRecord> = Vec::new();
    for &variant in &args.variants {
        let node_grid: Vec<usize> =
            if variant == Variant::Centralized { vec![1] } else { args.nodes.clone() };
        for &c in &node_grid {
            println!(
                "\n## Perf grid: {} {}(topology={}, backend={}, wire={}{}, exchange={})",
                variant.name(),
                if c > 1 { format!("{c}-node ") } else { String::new() },
                variant.topology_name(),
                args.backend.name(),
                args.wire.name(),
                if args.stream_exchange { ", streamed" } else { "" },
                args.exchange.name()
            );
            // Comm buckets: measured wall time, the total encoded bytes,
            // the per-iteration exchanged bytes (the α–β term the greedy
            // schedule shrinks), the deterministic β seconds those bytes
            // cost on this latency profile (jitter-free — the
            // compression factor is read off directly), the per-kind
            // byte split, and the violation-mass share the greedy rows
            // covered (`-` on full-exchange runs).
            println!(
                "{:>7} {:>5} {:>7} {:>8} {:>10} {:>10} {:>10} {:>7} {:>5} {:>12} {:>10} \
                 {:>10} {:>6} {:>30}",
                "n",
                "s",
                "N",
                "cond",
                "comp(s)",
                "comm(s)",
                "total(s)",
                "iters",
                "cvg",
                "wire(B)",
                "B/iter",
                "beta(s)",
                "viol%",
                "by-kind(B)"
            );
            for &n in &args.sizes {
                if n % c != 0 {
                    continue;
                }
                for &s in &args.sparsities {
                    for &nh in &args.hists {
                        for &cond in &args.conds {
                            let p = build_problem(n, nh, 0.05, s, 4, cond, 17 + n as u64);
                            // Damped step for the asynchronous exchange
                            // graphs (gossip's stale views need the same
                            // contraction margin as the async duals).
                            let alpha = match variant {
                                Variant::AsyncA2A | Variant::AsyncStar | Variant::Gossip => {
                                    args.alpha_async
                                }
                                _ => 1.0,
                            };
                            let cfg = SolveConfig {
                                variant,
                                backend: args.backend,
                                clients: c,
                                alpha,
                                net: args.net,
                                seed: n as u64 + c as u64,
                                wire: args.wire,
                                stream_exchange: args.stream_exchange,
                                wire_keyframe_every: args.wire_keyframe_every,
                                exchange: args.exchange,
                                greedy_topk: args.greedy_topk,
                                ..Default::default()
                            };
                            let (rec, _) = run_case_cfg(&p, &cfg, policy, (s, cond));
                            let kinds: Vec<String> = rec
                                .wire_bytes_by_kind
                                .iter()
                                .filter(|&&(_, b)| b > 0)
                                .map(|&(k, b)| format!("{k}={b}"))
                                .collect();
                            let viol = rec
                                .greedy_mass_fraction
                                .map(|f| format!("{:.1}", 100.0 * f))
                                .unwrap_or_else(|| "-".to_string());
                            println!(
                                "{:>7} {:>5} {:>7} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>7} \
                                 {:>5} {:>12} {:>10.0} {:>10.4} {:>6} {:>30}",
                                rec.n,
                                rec.sparsity,
                                rec.hists,
                                rec.cond,
                                rec.comp_secs,
                                rec.comm_secs,
                                rec.total_secs,
                                rec.iterations,
                                if rec.converged { "yes" } else { "no" },
                                rec.wire_bytes,
                                rec.wire_bytes_per_iter,
                                args.net.beta_secs(rec.wire_bytes),
                                viol,
                                kinds.join("/")
                            );
                            records.push(rec);
                        }
                    }
                }
            }
        }
    }

    let mut fields: Vec<(&str, Json)> = vec![
        ("experiment", "perf-grid".into()),
        ("wire_format", args.wire.name().into()),
        ("stream_exchange", args.stream_exchange.into()),
        ("exchange", args.exchange.name().into()),
        // β seconds = wire_bytes × this; emitting the coefficient keeps
        // the per-row β term recomputable from the document alone.
        ("beta_secs_per_byte", args.net.per_byte_secs.into()),
        ("rows", Json::Arr(records.iter().map(|r| r.to_json()).collect())),
    ];

    if args.fleet_compare {
        fields.push(("fleet_absorb", fleet_comparison(args)));
    }

    if args.chi2 {
        fields.push(("chi2", chi2_table(&records)));
    }

    let doc = Json::obj(fields);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}

/// Per-node vs fleet-synchronized rebuilds: the same small-ε
/// log-domain workload (the absorption-hybrid's home regime, native
/// backend — the XLA grid has no log lowering), every federated variant
/// run with per-node absorption decisions and with the
/// coordinator-broadcast `Gref` protocol. Reports both retruncation
/// totals (summed over nodes), the fleet command count, and the
/// slowest-node timings, so the amortization claim is measurable from
/// the emitted document.
fn fleet_comparison(args: &PerfGridArgs) -> Json {
    // τ = 5 forces several re-absorptions over the solve so the
    // comparison has signal; threshold/iters pinned for comparability.
    let (eps, nh, tau) = (0.005, 4, 5.0);
    let n = args.sizes.iter().copied().min().unwrap_or(256);
    let policy = StopPolicy {
        threshold: args.threshold.max(1e-8),
        max_iters: args.max_iters.max(4000),
        check_every: 1,
        ..Default::default()
    };
    println!(
        "\n## Fleet-synchronized absorption: per-node vs fleet rebuilds \
         (n={n}, N={nh}, eps={eps}, tau={tau}, log domain, native backend)"
    );
    println!(
        "{:>10} {:>3} | {:>7} {:>9} {:>10} | {:>7} {:>9} {:>7} {:>10} {:>5}",
        "variant",
        "c",
        "iters",
        "rebuilds",
        "total(s)",
        "iters",
        "rebuilds",
        "cmds",
        "total(s)",
        "cvg"
    );
    let mut rows = Vec::new();
    // One fixed workload for the whole comparison (the kernel caches on
    // `Problem` are shared, so every run truncates/absorbs from the
    // same dense kernel built exactly once).
    let p = build_problem(n, nh, eps, 0.0, 4, CondClass::Ill, 29 + n as u64);
    for &variant in &Variant::ALL_FEDERATED {
        for &c in &args.nodes {
            if n % c != 0 {
                continue;
            }
            let alpha = match variant {
                Variant::AsyncA2A | Variant::AsyncStar => args.alpha_async,
                _ => 1.0,
            };
            let run = |fleet: bool| {
                let cfg = SolveConfig {
                    variant,
                    backend: BackendKind::Native,
                    domain: DomainChoice::Log,
                    stab: Stabilization {
                        absorb_threshold: tau,
                        fleet_absorb: fleet,
                        ..Stabilization::default()
                    },
                    clients: c,
                    alpha,
                    net: args.net,
                    seed: n as u64 + c as u64,
                    // The comparison honors the requested wire/stream
                    // flags: Gref probe/command compression is exactly
                    // what a `--wire-format f32 --fleet-compare` run is
                    // meant to measure.
                    wire: args.wire,
                    stream_exchange: args.stream_exchange,
                    wire_keyframe_every: args.wire_keyframe_every,
                    ..Default::default()
                };
                run_case_cfg(&p, &cfg, policy, (0.0, CondClass::Ill))
            };
            let (base_rec, base_out) = run(false);
            let (fleet_rec, fleet_out) = run(true);
            let base_st = base_out.stab.clone().unwrap_or_default();
            let fleet_st = fleet_out.stab.clone().unwrap_or_default();
            println!(
                "{:>10} {:>3} | {:>7} {:>9} {:>10.3} | {:>7} {:>9} {:>7} {:>10.3} {:>5}",
                variant.name(),
                c,
                base_rec.iterations,
                base_st.rebuilds,
                base_rec.total_secs,
                fleet_rec.iterations,
                fleet_st.rebuilds,
                fleet_st.fleet_commands,
                fleet_rec.total_secs,
                if fleet_rec.converged { "yes" } else { "no" }
            );
            rows.push(Json::obj(vec![
                ("variant", variant.name().into()),
                ("clients", c.into()),
                ("n", n.into()),
                ("nhist", nh.into()),
                ("eps", eps.into()),
                ("tau", tau.into()),
                ("iterations_per_node", base_rec.iterations.into()),
                ("rebuilds_per_node", base_st.rebuilds.into()),
                ("absorbs_per_node", base_st.absorbs.into()),
                ("total_secs_per_node", base_rec.total_secs.into()),
                ("iterations_fleet", fleet_rec.iterations.into()),
                ("rebuilds_fleet", fleet_st.rebuilds.into()),
                ("absorbs_fleet", fleet_st.absorbs.into()),
                ("fleet_commands", fleet_st.fleet_commands.into()),
                ("fleet_rebuilds", fleet_st.fleet_rebuilds.into()),
                ("total_secs_fleet", fleet_rec.total_secs.into()),
                ("converged_fleet", fleet_rec.converged.into()),
            ]));
        }
    }
    Json::Arr(rows)
}

/// Table VI — χ² test of total execution time across the covariates
/// (algorithm type, node count, condition class) per input size.
fn chi2_table(records: &[RunRecord]) -> Json {
    println!("\n## Table VI: χ² on total execution time per input size");
    println!("{:>8} {:>14} {:>10} {:>6}", "n", "chi2", "p-value", "df");
    let mut sizes: Vec<usize> = records.iter().map(|r| r.n).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let mut rows = Vec::new();
    for n in sizes {
        // Cells: (variant, clients, cond) → total-time sum. Under the
        // null (no covariate effect) cell sums are proportional to cell
        // counts.
        use std::collections::BTreeMap;
        let mut sums: BTreeMap<(String, usize, String), (f64, usize)> = BTreeMap::new();
        for r in records.iter().filter(|r| r.n == n) {
            let e = sums
                .entry((r.variant.clone(), r.clients, r.cond.clone()))
                .or_insert((0.0, 0));
            e.0 += r.total_secs;
            e.1 += 1;
        }
        let total: f64 = sums.values().map(|v| v.0).sum();
        let count: usize = sums.values().map(|v| v.1).sum();
        if sums.len() < 2 || total <= 0.0 {
            continue;
        }
        let observed: Vec<f64> = sums.values().map(|v| v.0).collect();
        let expected: Vec<f64> = sums
            .values()
            .map(|v| total * v.1 as f64 / count as f64)
            .collect();
        // Scale to pseudo-counts for a meaningful χ² (times are not
        // counts; the paper applies the same liberty).
        let scale = 1000.0 / total;
        let obs: Vec<f64> = observed.iter().map(|x| x * scale).collect();
        let exp: Vec<f64> = expected.iter().map(|x| x * scale).collect();
        let x2 = chi2_stat(&obs, &exp);
        let df = sums.len() - 1;
        let p = chi2_sf(x2, df);
        println!("{n:>8} {x2:>14.1} {p:>10.3} {df:>6}");
        rows.push(Json::obj(vec![
            ("n", n.into()),
            ("chi2", x2.into()),
            ("p_value", p.into()),
            ("df", df.into()),
        ]));
    }
    Json::Arr(rows)
}
