//! App. A, Figs 26–28 — local iterations `w` before each broadcast:
//! the paper finds them "unequivocally detrimental". Traces the marginal
//! error vs iteration and vs wall time for w ∈ {1, 2, 4, 8}, sync and
//! async.

use super::{dump_json, Scale};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::net::LatencyModel;
use crate::sinkhorn::StopPolicy;
use crate::workload::ProblemSpec;

pub struct LocalItersArgs {
    pub n: usize,
    pub clients: usize,
    pub ws: Vec<usize>,
    pub max_iters: usize,
    pub backend: BackendKind,
    pub out: Option<String>,
}

impl LocalItersArgs {
    pub fn at_scale(scale: Scale) -> Self {
        Self {
            n: scale.sizes()[0],
            clients: 4,
            ws: vec![1, 2, 4, 8],
            max_iters: 1000,
            backend: BackendKind::Native,
            out: None,
        }
    }
}

pub fn run(args: &LocalItersArgs) -> anyhow::Result<Json> {
    println!(
        "# Figs 26-28: local iterations w (sync federation), n={}, c={}",
        args.n, args.clients
    );
    let p = ProblemSpec::new(args.n).with_eps(0.05).build(88);
    let policy = StopPolicy {
        threshold: 1e-12,
        max_iters: args.max_iters,
        check_every: 1,
        ..Default::default()
    };

    println!("{:>4} {:>10} {:>12} {:>14}", "w", "iters", "time (s)", "final err");
    let mut rows = Vec::new();
    for &w in &args.ws {
        let cfg = SolveConfig {
            variant: Variant::SyncA2A,
            backend: args.backend,
            clients: args.clients,
            local_iters: w,
            net: LatencyModel::lan(),
            ..Default::default()
        };
        let out = run_federated(&p, &cfg, policy, true);
        let ferr = out.trace.last().map(|t| t.err).unwrap_or(f64::NAN);
        println!("{:>4} {:>10} {:>12.3} {:>14.3e}", w, out.iterations, out.secs, ferr);
        rows.push(Json::obj(vec![
            ("w", w.into()),
            ("iterations", out.iterations.into()),
            ("secs", out.secs.into()),
            ("converged", out.converged.into()),
            ("final_err", ferr.into()),
            (
                "trace",
                Json::Arr(
                    out.trace
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("iter", t.iter.into()),
                                ("secs", t.secs.into()),
                                ("err", t.err.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }

    let doc = Json::obj(vec![
        ("experiment", "local-iters".into()),
        ("n", args.n.into()),
        ("clients", args.clients.into()),
        ("rows", Json::Arr(rows)),
    ]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
