//! Figs 4–5 — the ε-study on the paper's 4×4 worked example (§III-A).
//!
//! For each ε: trace of marginal errors on `a`/`b` and the objective vs
//! iterations (Fig 4); the converged objective vs ε approaching
//! ⟨P,C⟩ ≈ 0.3 (Fig 5); the minimal iteration count I_min for the
//! *objective* to converge (the paper's definition), which scales like
//! 1/ε.
//!
//! Precision note: the paper runs this study at 50-decimal precision and
//! observes the rounding collapse at ε = 1e-6. In f64 the same collapse
//! (Gibbs entries underflow to exact 0 → NaN marginals) appears at
//! ε ≲ 2e-3 for this cost matrix (max C / ε > 745 overflows exp), so the
//! default *linear* sweep stays above it and one deliberately-collapsing
//! ε is included to reproduce the phenomenon.
//!
//! The **small-ε extension** then reruns the collapse regime in the
//! log-stabilized domain (`--domain log` internals: logsumexp with max
//! absorption), where ε = 1e-3 … 1e-4 converge routinely — the sweep the
//! linear path cannot complete at any iteration budget.

use super::dump_json;
use crate::config::{BackendKind, DomainChoice};
use crate::jsonio::Json;
use crate::linalg::Domain;
use crate::runtime::make_backend;
use crate::sinkhorn::{CentralizedSolver, StopPolicy};
use crate::workload::Problem;

pub struct EpsilonArgs {
    /// Main sweep (run in `domain`, linear by default to exhibit the
    /// collapse).
    pub epsilons: Vec<f64>,
    /// Log-domain extension sweep below the f64 linear floor (empty =
    /// skip).
    pub small_epsilons: Vec<f64>,
    /// Domain for the main sweep.
    pub domain: DomainChoice,
    pub max_iters: usize,
    pub out: Option<String>,
}

impl Default for EpsilonArgs {
    fn default() -> Self {
        Self {
            // Descending sweep + one value in the f64-collapse regime.
            epsilons: vec![5e-1, 1e-1, 5e-2, 2e-2, 1e-2, 1e-3],
            small_epsilons: vec![1e-3, 5e-4, 1e-4],
            domain: DomainChoice::Linear,
            max_iters: 2_000_000,
            out: None,
        }
    }
}

/// One sweep row: traced solve at `eps` in `domain`, I_min post hoc.
fn sweep_row(
    solver: &CentralizedSolver,
    eps: f64,
    domain: Domain,
    max_iters: usize,
) -> Json {
    let p = Problem::paper_4x4(eps);
    // Fixed budget scaled to the expected 1/ε iteration count.
    let budget = ((40.0 / eps) as usize + 2000).min(max_iters);
    let policy = StopPolicy {
        threshold: 0.0, // run the whole budget; I_min found post hoc
        max_iters: budget,
        check_every: (budget / 400).max(1),
        ..Default::default()
    };
    let out = solver.solve_traced_in(&p, policy, 1.0, domain);
    let last = out.history.last().copied();
    let (ea, eb, obj_final) = last
        .map(|h| (h.err_a, h.err_b, h.objective))
        .unwrap_or((f64::NAN, f64::NAN, f64::NAN));

    // I_min: first trace point whose objective is within 1e-10 of the
    // final value — the paper's "objective converged" criterion.
    let collapsed = !obj_final.is_finite() || !ea.is_finite() || !eb.is_finite();
    let i_min = if collapsed {
        budget
    } else {
        out.history
            .iter()
            .find(|h| (h.objective - obj_final).abs() <= 1e-10 * obj_final.abs().max(1.0))
            .map(|h| h.iter)
            .unwrap_or(budget)
    };
    println!(
        "{:>10.0e} {:>7} {:>10} {:>14.3e} {:>14.3e} {:>14.6} {:>10.2}{}",
        eps,
        domain.name(),
        i_min,
        ea,
        eb,
        obj_final,
        i_min as f64 * eps,
        if collapsed {
            "   <- f64 rounding collapse (paper: at 1e-6 with 50-digit)"
        } else {
            ""
        }
    );
    Json::obj(vec![
        ("eps", eps.into()),
        ("domain", domain.name().into()),
        ("i_min", i_min.into()),
        ("budget", budget.into()),
        ("collapsed", collapsed.into()),
        ("objective", obj_final.into()),
        ("err_a", ea.into()),
        ("err_b", eb.into()),
        (
            "trace",
            Json::Arr(
                out.history
                    .iter()
                    .step_by(4)
                    .map(|h| {
                        Json::obj(vec![
                            ("iter", h.iter.into()),
                            ("err_a", h.err_a.into()),
                            ("err_b", h.err_b.into()),
                            ("objective", h.objective.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

pub fn run(args: &EpsilonArgs) -> anyhow::Result<Json> {
    let backend = make_backend(BackendKind::Native, "", 1)?;
    let solver = CentralizedSolver::new(backend);

    println!("# Figs 4-5: epsilon study on the 4x4 worked example");
    println!(
        "{:>10} {:>7} {:>10} {:>14} {:>14} {:>14} {:>10}",
        "eps", "domain", "I_min", "err_a", "err_b", "objective", "I_min*eps"
    );

    let mut rows = Vec::new();
    for &eps in &args.epsilons {
        let domain = args.domain.resolve(&Problem::paper_4x4(eps));
        rows.push(sweep_row(&solver, eps, domain, args.max_iters));
    }

    if !args.small_epsilons.is_empty() {
        println!("# small-eps extension: log-stabilized domain (linear underflows here)");
        for &eps in &args.small_epsilons {
            rows.push(sweep_row(&solver, eps, Domain::Log, args.max_iters));
        }
    }

    let doc = Json::obj(vec![("experiment", "epsilon-study".into()), ("rows", Json::Arr(rows))]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
