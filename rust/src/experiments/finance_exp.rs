//! §V + Fig 25 — the financial application.
//!
//! `--paper-example` reproduces the §V-B4 worked example (3 assets,
//! ρ_worst = −0.48) across the three settings of Fig 25, reporting each
//! setting's convergence time. Without it, a larger synthetic portfolio
//! (the proprietary-data substitution, DESIGN.md §3) goes through the
//! full λ-search pipeline.

use super::dump_json;
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::finance::{
    normalize_returns, synthetic_portfolio, worst_case_loss, LambdaSearch, WorstCaseSpec,
};
use crate::jsonio::Json;
use crate::net::LatencyModel;
use crate::sinkhorn::StopPolicy;

pub struct FinanceArgs {
    pub paper_example: bool,
    pub scenarios: usize,
    pub assets: usize,
    pub clients: usize,
    pub backend: BackendKind,
    pub out: Option<String>,
}

impl Default for FinanceArgs {
    fn default() -> Self {
        Self {
            paper_example: true,
            scenarios: 64,
            assets: 12,
            clients: 4,
            backend: BackendKind::Native,
            out: None,
        }
    }
}

pub fn run(args: &FinanceArgs) -> anyhow::Result<Json> {
    let mut fields: Vec<(&str, Json)> = vec![("experiment", "finance".into())];

    if args.paper_example {
        let spec = WorstCaseSpec::paper_example();
        println!("# §V-B4 worked example (3 assets) + Fig 25 timings");
        let mut rows = Vec::new();
        for (variant, clients) in [
            (Variant::SyncA2A, 3usize),
            (Variant::SyncStar, 3),
            (Variant::AsyncA2A, 3),
        ] {
            let cfg = SolveConfig {
                variant,
                backend: args.backend,
                clients,
                alpha: if variant == Variant::AsyncA2A { 0.5 } else { 1.0 },
                net: LatencyModel::lan(),
                ..Default::default()
            };
            let policy =
                StopPolicy { threshold: 1e-12, max_iters: 20_000, ..Default::default() };
            let res = worst_case_loss(&spec, &cfg, policy, LambdaSearch::fixed(spec.lambda));
            println!(
                "  {:<12} ρ_worst = {:+.4}  ⟨P,c⟩ = {:.6}  inner iters = {}  time = {:.3}s  converged = {}",
                variant.name(),
                res.rho,
                res.transport_cost,
                res.inner_iters,
                res.secs,
                res.converged
            );
            rows.push(Json::obj(vec![
                ("variant", variant.name().into()),
                ("rho_worst", res.rho.into()),
                ("transport_cost", res.transport_cost.into()),
                ("inner_iters", res.inner_iters.into()),
                ("secs", res.secs.into()),
                ("converged", res.converged.into()),
            ]));
        }
        println!("  paper reference: ρ_worst ≈ −0.48");
        fields.push(("paper_example", Json::Arr(rows)));
    } else {
        println!(
            "# Synthetic portfolio: {} assets, {} scenarios, λ-search to δ",
            args.assets, args.scenarios
        );
        let data = synthetic_portfolio(args.assets, args.scenarios, 2026);
        // Scenario-level worst case: historical portfolio returns are
        // the empirical support, analyst views the target support.
        let spec = WorstCaseSpec {
            returns: data.historical.clone(),
            targets: data.analyst_view.clone(),
            weights: vec![1.0 / args.scenarios as f64; args.scenarios],
            lambda: 0.5,
            delta: 0.0, // set below from a probe
            eps: 0.01,
            margin: 0.01,
        };
        let cfg = SolveConfig {
            variant: Variant::SyncA2A,
            backend: args.backend,
            clients: args.clients,
            net: LatencyModel::lan(),
            ..Default::default()
        };
        let policy = StopPolicy { threshold: 1e-10, max_iters: 20_000, ..Default::default() };
        let probe = worst_case_loss(&spec, &cfg, policy, LambdaSearch::fixed(1.0));
        let mut spec2 = spec.clone();
        spec2.delta = probe.transport_cost * 1.5;
        let res = worst_case_loss(
            &spec2,
            &cfg,
            policy,
            LambdaSearch::bisection(1e-3, 32.0, spec2.delta * 1e-3, 30),
        );
        let (xt, _, _) = normalize_returns(&spec.returns, &spec.targets, spec.margin);
        println!(
            "  λ* = {:.4}  ⟨P,c⟩ = {:.6} (δ = {:.6})  ρ_worst = {:+.4}  λ-evals = {}  time = {:.3}s",
            res.lambda, res.transport_cost, spec2.delta, res.rho, res.lambda_iters, res.secs
        );
        println!("  (historical mean normalized return = {:.4})", xt.iter().sum::<f64>() / xt.len() as f64);
        fields.push((
            "synthetic",
            Json::obj(vec![
                ("assets", args.assets.into()),
                ("scenarios", args.scenarios.into()),
                ("lambda_star", res.lambda.into()),
                ("delta", spec2.delta.into()),
                ("transport_cost", res.transport_cost.into()),
                ("rho_worst", res.rho.into()),
                ("lambda_evals", res.lambda_iters.into()),
                ("secs", res.secs.into()),
                ("converged", res.converged.into()),
            ]),
        ));
    }

    let doc = Json::obj(fields);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
