//! Fig 9 (+ Figs 21–22 at CPU speed) — async non-determinism: repeated
//! runs of the asynchronous federation on one problem, tracing the
//! marginal error at node 0.

use super::{dump_json, Scale};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::metrics::Summary;
use crate::net::LatencyModel;
use crate::sinkhorn::StopPolicy;
use crate::workload::ProblemSpec;

pub struct AsyncStudyArgs {
    pub n: usize,
    pub clients: usize,
    pub alpha: f64,
    pub runs: usize,
    pub max_iters: usize,
    pub threshold: f64,
    pub backend: BackendKind,
    pub net: LatencyModel,
    pub out: Option<String>,
}

impl AsyncStudyArgs {
    pub fn at_scale(scale: Scale) -> Self {
        Self {
            n: *scale.sizes().last().unwrap(),
            clients: 2,
            alpha: 1.0, // Fig 9 runs the undamped algorithm
            runs: scale.repeats().max(3),
            max_iters: 2000,
            threshold: 1e-10,
            backend: BackendKind::Xla,
            net: LatencyModel::lan(),
            out: None,
        }
    }
}

pub fn run(args: &AsyncStudyArgs) -> anyhow::Result<Json> {
    println!(
        "# Fig 9: async non-determinism, n={}, c={}, α={}, {} runs",
        args.n, args.clients, args.alpha, args.runs
    );
    let p = ProblemSpec::new(args.n).with_eps(0.05).build(41);
    let policy = StopPolicy {
        threshold: args.threshold,
        max_iters: args.max_iters,
        check_every: 10,
        ..Default::default()
    };

    let mut finals = Vec::new();
    let mut n_converged = 0usize;
    let mut runs = Vec::new();
    for r in 0..args.runs {
        let cfg = SolveConfig {
            variant: Variant::AsyncA2A,
            backend: args.backend,
            clients: args.clients,
            alpha: args.alpha,
            net: args.net,
            seed: 9000 + r as u64,
            ..Default::default()
        };
        let out = run_federated(&p, &cfg, policy, true);
        let last = out.trace.last().map(|t| t.err).unwrap_or(f64::NAN);
        finals.push(last);
        n_converged += out.converged as usize;
        println!(
            "  run {r:>2}: stop={:?} iters={} final marginal err={last:.3e}",
            out.stop, out.iterations
        );
        runs.push(Json::obj(vec![
            ("run", r.into()),
            ("converged", out.converged.into()),
            ("iterations", out.iterations.into()),
            ("final_err", last.into()),
            (
                "trace",
                Json::Arr(
                    out.trace
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("iter", t.iter.into()),
                                ("secs", t.secs.into()),
                                ("err", t.err.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    let s = Summary::of(&finals);
    println!(
        "  final-error mean={:.3e} std={:.3e}; {}/{} runs converged",
        s.mean, s.std, n_converged, args.runs
    );

    let doc = Json::obj(vec![
        ("experiment", "async-study".into()),
        ("n", args.n.into()),
        ("clients", args.clients.into()),
        ("alpha", args.alpha.into()),
        ("mean_final_err", s.mean.into()),
        ("std_final_err", s.std.into()),
        ("converged_runs", n_converged.into()),
        ("runs", Json::Arr(runs)),
    ]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
