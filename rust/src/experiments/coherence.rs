//! §IV-B1 — coherence check: the synchronous federations reproduce the
//! centralized objective exactly for node counts 1, 2, 4 (Prop. 1).

use super::{build_problem, dump_json};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::net::LatencyModel;
use crate::runtime::make_backend;
use crate::sinkhorn::{objective, CentralizedSolver, StopPolicy};
use crate::workload::CondClass;

pub struct CoherenceArgs {
    pub n: usize,
    pub eps: f64,
    pub backend: BackendKind,
    pub out: Option<String>,
}

impl Default for CoherenceArgs {
    fn default() -> Self {
        Self { n: 256, eps: 0.05, backend: BackendKind::Native, out: None }
    }
}

pub fn run(args: &CoherenceArgs) -> anyhow::Result<Json> {
    let p = build_problem(args.n, 1, args.eps, 0.0, 4, CondClass::Well, 2024);
    let policy = StopPolicy { threshold: 1e-12, max_iters: 10_000, ..Default::default() };

    let be = make_backend(args.backend, &crate::config::default_artifacts_dir(), 1)?;
    let central = CentralizedSolver::new(be).solve(&p, policy, 1.0);
    let obj_c = objective(&p, &central.state, 0);
    println!("# §IV-B1 coherence: objective must be identical across node counts");
    println!("centralized: objective = {obj_c:.15}");

    let mut rows = vec![Json::obj(vec![
        ("setting", "centralized".into()),
        ("nodes", 1usize.into()),
        ("objective", obj_c.into()),
        ("delta_vs_central", 0.0.into()),
    ])];

    for variant in [Variant::SyncA2A, Variant::SyncStar] {
        for clients in [1usize, 2, 4] {
            if args.n % clients != 0 {
                continue;
            }
            let cfg = SolveConfig {
                variant,
                backend: args.backend,
                clients,
                net: LatencyModel::zero(),
                ..Default::default()
            };
            let out = run_federated(&p, &cfg, policy, false);
            let obj = objective(&p, &out.state, 0);
            let delta = (obj - obj_c).abs();
            println!(
                "{:>10} c={}: objective = {obj:.15} (|Δ| = {delta:.3e})",
                variant.name(),
                clients
            );
            assert!(delta < 1e-9, "coherence violated: {delta}");
            rows.push(Json::obj(vec![
                ("setting", variant.name().into()),
                ("nodes", clients.into()),
                ("objective", obj.into()),
                ("delta_vs_central", delta.into()),
            ]));
        }
    }
    println!("coherence OK (all |Δ| < 1e-9)");

    let doc = Json::obj(vec![("experiment", "coherence".into()), ("rows", Json::Arr(rows))]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}
