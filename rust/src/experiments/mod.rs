//! Experiment drivers — one per paper table/figure (DESIGN.md §5).
//!
//! Each driver prints the paper-shaped rows/series to stdout and returns
//! a [`Json`] document that the launcher can dump with `--out FILE`.
//! Scales: the default sizes are laptop-class stand-ins for the paper's
//! cluster sizes; `FEDSINK_SCALE=paper` (or `--scale paper`) selects the
//! original `n`/`N` grids.

pub mod async_study;
pub mod coherence;
pub mod delays;
pub mod epsilon;
pub mod finance_exp;
pub mod local_iters;
pub mod perf_grid;
pub mod robustness;
pub mod stepsize;
pub mod timing;
pub mod vectorized;

use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::{run_federated, slowest_node, FederatedOutcome};
use crate::jsonio::Json;
use crate::metrics::RunRecord;
use crate::net::LatencyModel;
use crate::sinkhorn::StopPolicy;
use crate::workload::{CondClass, Problem, ProblemSpec};

/// Experiment scale: `default` keeps every driver under ~minutes on a
/// few CPU cores; `paper` restores the published grids; `quick` is a CI
/// smoke setting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Default,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "quick" => Some(Scale::Quick),
            "default" => Some(Scale::Default),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    pub fn from_env() -> Scale {
        std::env::var("FEDSINK_SCALE")
            .ok()
            .and_then(|s| Scale::parse(&s))
            .unwrap_or(Scale::Default)
    }

    /// The paper's problem sizes n ∈ {1k, 5k, 10k} → scaled grids.
    pub fn sizes(self) -> Vec<usize> {
        match self {
            Scale::Quick => vec![64],
            Scale::Default => vec![256, 512, 1024],
            Scale::Paper => vec![1000, 5000, 10000],
        }
    }

    pub fn node_counts(self) -> Vec<usize> {
        match self {
            Scale::Quick => vec![1, 2],
            _ => vec![1, 2, 4, 8],
        }
    }

    pub fn repeats(self) -> usize {
        match self {
            Scale::Quick => 2,
            Scale::Default => 5,
            Scale::Paper => 15,
        }
    }
}

/// Shared solve wrapper: runs a variant and flattens the outcome into the
/// slowest-node summary row used by every appendix table.
#[allow(clippy::too_many_arguments)]
pub fn run_case(
    p: &Problem,
    variant: Variant,
    clients: usize,
    backend: BackendKind,
    net: LatencyModel,
    policy: StopPolicy,
    alpha: f64,
    seed: u64,
    spec_info: (f64, CondClass),
) -> (RunRecord, FederatedOutcome) {
    let cfg = SolveConfig {
        variant,
        backend,
        clients,
        alpha,
        net,
        seed,
        ..Default::default()
    };
    run_case_cfg(p, &cfg, policy, spec_info)
}

/// [`run_case`] with an explicit full [`SolveConfig`] — drivers that pin
/// the numerics domain or the stabilized-engine tuning (e.g. the
/// fleet-absorption comparison) go through here.
pub fn run_case_cfg(
    p: &Problem,
    cfg: &SolveConfig,
    policy: StopPolicy,
    spec_info: (f64, CondClass),
) -> (RunRecord, FederatedOutcome) {
    let out = run_federated(p, cfg, policy, false);
    let slow = slowest_node(&out.node_stats);
    let wire_bytes_by_kind: Vec<(&'static str, u64)> =
        out.traffic.by_kind.iter().map(|&(name, bytes, _)| (name, bytes)).collect();
    let wire_bytes_per_iter = if out.iterations > 0 {
        out.traffic.total_bytes as f64 / out.iterations as f64
    } else {
        0.0
    };
    let rec = RunRecord {
        variant: cfg.variant.name().to_string(),
        topology: cfg.variant.topology_name().to_string(),
        n: p.n,
        clients: cfg.clients,
        hists: p.hists(),
        sparsity: spec_info.0,
        cond: spec_info.1.name().to_string(),
        iterations: out.iterations,
        converged: out.converged,
        comp_secs: slow.comp_secs(),
        comm_secs: slow.comm_secs(),
        total_secs: slow.total_secs(),
        final_err: slow.final_err,
        wire_bytes: out.traffic.total_bytes,
        wire_bytes_by_kind,
        exchange: cfg.exchange.name().to_string(),
        wire_bytes_per_iter,
        greedy_row_fraction: out.greedy.as_ref().map(|g| g.row_fraction()),
        greedy_mass_fraction: out.greedy.as_ref().map(|g| g.mass_fraction()),
    };
    (rec, out)
}

/// Build a problem from the common spec parameters.
pub fn build_problem(
    n: usize,
    hists: usize,
    eps: f64,
    sparsity: f64,
    blocks: usize,
    cond: CondClass,
    seed: u64,
) -> Problem {
    ProblemSpec::new(n)
        .with_hists(hists)
        .with_eps(eps)
        .with_sparsity(sparsity, blocks)
        .with_condition(cond)
        .build(seed)
}

/// Write a JSON document to `path` (pretty, deterministic key order).
pub fn dump_json(path: &str, doc: &Json) -> anyhow::Result<()> {
    std::fs::write(path, crate::jsonio::to_string_pretty(doc))?;
    println!("wrote {path}");
    Ok(())
}

/// Format seconds like the paper tables (3 decimals).
pub fn fmt_s(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parsing_and_grids() {
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert_eq!(Scale::parse("nope"), None);
        assert!(Scale::Quick.sizes().len() < Scale::Paper.sizes().len());
        assert_eq!(Scale::Default.node_counts(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn run_case_produces_record() {
        let p = build_problem(16, 1, 0.5, 0.0, 4, CondClass::Well, 1);
        let (rec, out) = run_case(
            &p,
            Variant::SyncA2A,
            2,
            BackendKind::Native,
            LatencyModel::zero(),
            StopPolicy { threshold: 1e-10, max_iters: 2000, ..Default::default() },
            1.0,
            1,
            (0.0, CondClass::Well),
        );
        assert!(rec.converged && out.converged);
        assert_eq!(rec.variant, "sync-a2a");
        assert_eq!(rec.topology, "a2a");
        assert!(rec.total_secs >= rec.comm_secs);
        // The wire counters ride along: a federated run moves U, V and
        // Ctl bytes, and the kind-generic split sums to the total.
        assert!(rec.wire_bytes > 0);
        let kind_sum: u64 = rec.wire_bytes_by_kind.iter().map(|&(_, b)| b).sum();
        assert_eq!(rec.wire_bytes, kind_sum);
        assert!(rec.bytes_of("U") > 0 && rec.bytes_of("V") > 0);
        assert_eq!(rec.exchange, "full");
        assert!(rec.wire_bytes_per_iter > 0.0);
        assert!(rec.greedy_row_fraction.is_none());
    }
}
