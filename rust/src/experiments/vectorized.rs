//! §IV-B3 + Figs 7–8 — Cuturi vectorization over N target histograms.
//!
//! Three measurements:
//! * serial-vs-vectorized (§IV-B3): solving N problems one-by-one vs one
//!   n×N solve — the paper reports 11.56 s vs 0.31 s at N = 500;
//! * Fig 7: isolated *compute* time vs N across federated settings;
//! * Fig 8: isolated *communication* time vs N.

use super::{dump_json, Scale};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::linalg::Mat;
use crate::net::LatencyModel;
use crate::sinkhorn::StopPolicy;
use crate::workload::{Problem, ProblemSpec};

pub struct VectorizedArgs {
    pub n: usize,
    pub hist_grid: Vec<usize>,
    pub nodes: Vec<usize>,
    pub iters: usize,
    pub backend: BackendKind,
    pub net: LatencyModel,
    /// Also run the serial-vs-vectorized comparison at this N.
    pub serial_compare: Option<usize>,
    pub out: Option<String>,
}

impl VectorizedArgs {
    pub fn at_scale(scale: Scale) -> Self {
        match scale {
            Scale::Quick => Self {
                n: 64,
                hist_grid: vec![1, 8, 64],
                nodes: vec![1, 2],
                iters: 15,
                backend: BackendKind::Xla,
                net: LatencyModel::lan(),
                serial_compare: Some(8),
                out: None,
            },
            Scale::Default => Self {
                n: 512,
                hist_grid: vec![1, 64, 512, 4096],
                nodes: vec![1, 2, 4],
                iters: 15,
                backend: BackendKind::Xla,
                net: LatencyModel::lan(),
                serial_compare: Some(500),
                out: None,
            },
            Scale::Paper => Self {
                n: 1000,
                hist_grid: vec![1, 1000, 5000, 10000, 50000, 75000, 100000],
                nodes: vec![1, 2, 4],
                iters: 15,
                backend: BackendKind::Xla,
                net: LatencyModel::lan(),
                serial_compare: Some(500),
                out: None,
            },
        }
    }
}

pub fn run(args: &VectorizedArgs) -> anyhow::Result<Json> {
    let mut doc_fields: Vec<(&str, Json)> = vec![
        ("experiment", "vectorized".into()),
        ("n", args.n.into()),
    ];

    // --- §IV-B3 serial vs vectorized -----------------------------------
    if let Some(nh) = args.serial_compare {
        let p = ProblemSpec::new(args.n).with_hists(nh).with_eps(0.1).build(31);
        let policy = StopPolicy {
            threshold: 0.0,
            max_iters: args.iters,
            check_every: args.iters + 1,
            ..Default::default()
        };
        let cfg = SolveConfig {
            variant: Variant::Centralized,
            backend: args.backend,
            clients: 1,
            net: LatencyModel::zero(),
            ..Default::default()
        };
        // One vectorized solve of all N problems.
        let t0 = std::time::Instant::now();
        let _ = run_federated(&p, &cfg, policy, false);
        let vec_secs = t0.elapsed().as_secs_f64();
        // One single-histogram solve …
        let single = single_hist_problem(&p, 0);
        let t1 = std::time::Instant::now();
        let _ = run_federated(&single, &cfg, policy, false);
        let one_secs = t1.elapsed().as_secs_f64();
        // … and the serial loop over all N (extrapolated from a probe of
        // up to 16 solves to keep the driver fast; the scaling is exact
        // since every solve is identical work).
        let probe = nh.min(16);
        let t2 = std::time::Instant::now();
        for h in 0..probe {
            let ph = single_hist_problem(&p, h);
            let _ = run_federated(&ph, &cfg, policy, false);
        }
        let serial_secs = t2.elapsed().as_secs_f64() / probe as f64 * nh as f64;
        println!("# §IV-B3 serial vs vectorized at n={}, N={nh}, {} iters", args.n, args.iters);
        println!("  1 problem:            {one_secs:.3}s");
        println!("  {nh} problems vectorized: {vec_secs:.3}s");
        println!("  {nh} problems serially:   {serial_secs:.3}s (extrapolated from {probe})");
        doc_fields.push((
            "serial_compare",
            Json::obj(vec![
                ("nhist", nh.into()),
                ("one_secs", one_secs.into()),
                ("vectorized_secs", vec_secs.into()),
                ("serial_secs", serial_secs.into()),
            ]),
        ));
    }

    // --- Figs 7–8: compute / comm time vs N across settings ------------
    println!(
        "# Figs 7-8: isolated comp/comm time vs N (n={}, {} iters, backend={})",
        args.n,
        args.iters,
        args.backend.name()
    );
    println!("{:>8} {:>6} {:>12} {:>12}", "N", "nodes", "comp (s)", "comm (s)");
    let policy = StopPolicy {
        threshold: 0.0,
        max_iters: args.iters,
        check_every: args.iters + 1,
        ..Default::default()
    };
    let mut rows = Vec::new();
    for &nh in &args.hist_grid {
        let p = ProblemSpec::new(args.n).with_hists(nh).with_eps(0.1).build(33);
        for &c in &args.nodes {
            if args.n % c != 0 {
                continue;
            }
            let variant = if c == 1 { Variant::Centralized } else { Variant::SyncA2A };
            let cfg = SolveConfig {
                variant,
                backend: args.backend,
                clients: c,
                net: args.net,
                ..Default::default()
            };
            let out = run_federated(&p, &cfg, policy, false);
            let slow = crate::coordinator::slowest_node(&out.node_stats);
            println!(
                "{:>8} {:>6} {:>12.3} {:>12.3}",
                nh,
                c,
                slow.comp_secs(),
                slow.comm_secs()
            );
            rows.push(Json::obj(vec![
                ("nhist", nh.into()),
                ("nodes", c.into()),
                ("comp_secs", slow.comp_secs().into()),
                ("comm_secs", slow.comm_secs().into()),
            ]));
        }
    }
    doc_fields.push(("rows", Json::Arr(rows)));

    let doc = Json::obj(doc_fields);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}

/// Extract histogram `h` as a standalone single-histogram problem.
fn single_hist_problem(p: &Problem, h: usize) -> Problem {
    let mut b = Mat::zeros(p.n, 1);
    for i in 0..p.n {
        b[(i, 0)] = p.b[(i, h)];
    }
    let mut single = Problem::from_parts(p.a.clone(), b, p.cost.clone(), p.eps);
    single.masked_cost_min = p.masked_cost_min;
    single
}
