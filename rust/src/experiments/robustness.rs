//! Tables II–IV + Fig 13 — convergence robustness grids.
//!
//! Randomized inputs per simulation; categories (§IV-C2):
//! * threshold: loose 1e-5 / tight 1e-12;
//! * timeout: fast 10 s / slow 1200 s (scaled down by default);
//! * divergence: not converged within 3000 iterations.
//!
//! Reports, per (setting × node count): average time per execution, % of
//! convergence, % of timeout, % of divergence. `--sweep-alpha` adds the
//! Fig 13 α-sweep (fraction of converged runs vs α).

use super::{dump_json, Scale};
use crate::config::{BackendKind, SolveConfig, Variant};
use crate::coordinator::run_federated;
use crate::jsonio::Json;
use crate::metrics::Summary;
use crate::net::{FaultPlan, LatencyModel, Recovery};
use crate::sinkhorn::{StopPolicy, StopReason};
use crate::workload::ProblemSpec;

pub struct RobustnessArgs {
    pub n: usize,
    pub nodes: Vec<usize>,
    pub runs: usize,
    /// (label, threshold)
    pub thresholds: Vec<(&'static str, f64)>,
    /// (label, timeout seconds)
    pub timeouts: Vec<(&'static str, f64)>,
    pub divergence_iters: usize,
    pub alpha_async: f64,
    pub sweep_alpha: Option<Vec<f64>>,
    pub backend: BackendKind,
    pub out: Option<String>,
    /// Fault plan replayed in every cell run (inactive by default) and
    /// the recovery policy that answers it — the chaos columns of the
    /// grid report what the plan actually did.
    pub faults: FaultPlan,
    pub recovery: Recovery,
}

impl RobustnessArgs {
    pub fn at_scale(scale: Scale) -> Self {
        let (fast, slow) = match scale {
            Scale::Quick => (0.5, 5.0),
            Scale::Default => (2.0, 60.0),
            Scale::Paper => (10.0, 1200.0),
        };
        Self {
            n: scale.sizes()[scale.sizes().len() / 2],
            nodes: match scale {
                Scale::Quick => vec![2],
                _ => vec![2, 4, 8],
            },
            runs: scale.repeats(),
            thresholds: vec![("loose", 1e-5), ("tight", 1e-12)],
            timeouts: vec![("fast", fast), ("slow", slow)],
            divergence_iters: 3000,
            alpha_async: 0.5,
            sweep_alpha: None,
            backend: BackendKind::Native,
            out: None,
            faults: FaultPlan::none(),
            recovery: Recovery::default(),
        }
    }
}

struct GridCell {
    avg_secs: f64,
    pct_conv: f64,
    pct_timeout: f64,
    pct_div: f64,
    /// % of runs that lost a node (crash injection or struck peer).
    pct_lost: f64,
    /// Fault-layer counters summed across the cell's runs.
    drops: u64,
    dups: u64,
    retransmits: u64,
}

pub fn run(args: &RobustnessArgs) -> anyhow::Result<Json> {
    let settings: Vec<(&str, Variant, f64)> = vec![
        ("Synchronous All-To-All", Variant::SyncA2A, 1.0),
        ("Synchronous Star-Network", Variant::SyncStar, 1.0),
        ("Asynchronous", Variant::AsyncA2A, args.alpha_async),
        // The decentralized topologies on the same grid: the ring is
        // lock-step (α = 1), gossip needs the async damping margin.
        ("Synchronous Ring", Variant::Ring, 1.0),
        ("Gossip", Variant::Gossip, args.alpha_async),
    ];

    let mut tables = Vec::new();
    for &c in &args.nodes {
        if args.n % c != 0 {
            continue;
        }
        println!("\n## Tables II-IV: robustness grid, {c} nodes (n={}, {} runs/cell)", args.n, args.runs);
        let mut setting_rows = Vec::new();
        for (label, variant, alpha) in &settings {
            println!(
                "### {label} [topology={}]{}",
                variant.topology_name(),
                if *alpha != 1.0 { format!(" (α={alpha})") } else { String::new() }
            );
            println!(
                "{:>8} {:>8} {:>12} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8} {:>8}",
                "limit", "thresh", "avg time(s)", "% conv", "% t/out", "% div", "% lost",
                "drops", "dups", "rexmit"
            );
            let mut cells = Vec::new();
            for (tl_label, timeout) in &args.timeouts {
                for (th_label, threshold) in &args.thresholds {
                    let cell = grid_cell(args, *variant, c, *alpha, *threshold, *timeout);
                    println!(
                        "{:>8} {:>8} {:>12.2} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>8} {:>8} {:>8}",
                        tl_label, th_label, cell.avg_secs, cell.pct_conv, cell.pct_timeout,
                        cell.pct_div, cell.pct_lost, cell.drops, cell.dups, cell.retransmits
                    );
                    cells.push(Json::obj(vec![
                        ("limit", (*tl_label).into()),
                        ("threshold", (*th_label).into()),
                        ("avg_secs", cell.avg_secs.into()),
                        ("pct_convergence", cell.pct_conv.into()),
                        ("pct_timeout", cell.pct_timeout.into()),
                        ("pct_divergence", cell.pct_div.into()),
                        ("pct_node_loss", cell.pct_lost.into()),
                        ("drops", (cell.drops as f64).into()),
                        ("dups", (cell.dups as f64).into()),
                        ("retransmits", (cell.retransmits as f64).into()),
                    ]));
                }
            }
            setting_rows.push(Json::obj(vec![
                ("setting", (*label).into()),
                ("variant", variant.name().into()),
                ("topology", variant.topology_name().into()),
                ("alpha", (*alpha).into()),
                ("cells", Json::Arr(cells)),
            ]));
        }
        tables.push(Json::obj(vec![("nodes", c.into()), ("settings", Json::Arr(setting_rows))]));
    }

    // Fig 13: α-sweep of convergence fraction (slow-loose criteria).
    let mut sweep = Vec::new();
    if let Some(alphas) = &args.sweep_alpha {
        println!("\n## Fig 13: % of simulations converged vs α (slow/loose)");
        let c = args.nodes[0];
        let (_, slow) = args.timeouts[args.timeouts.len() - 1];
        for &alpha in alphas {
            let cell = grid_cell(args, Variant::AsyncA2A, c, alpha, 1e-5, slow);
            println!("  α={alpha:<8} → {:.1}% converged", cell.pct_conv);
            sweep.push(Json::obj(vec![
                ("alpha", alpha.into()),
                ("pct_convergence", cell.pct_conv.into()),
            ]));
        }
    }

    let doc = Json::obj(vec![
        ("experiment", "robustness".into()),
        ("n", args.n.into()),
        ("runs_per_cell", args.runs.into()),
        ("tables", Json::Arr(tables)),
        ("alpha_sweep", Json::Arr(sweep)),
    ]);
    if let Some(path) = &args.out {
        dump_json(path, &doc)?;
    }
    Ok(doc)
}

fn grid_cell(
    args: &RobustnessArgs,
    variant: Variant,
    clients: usize,
    alpha: f64,
    threshold: f64,
    timeout: f64,
) -> GridCell {
    let mut times = Vec::new();
    let (mut conv, mut tout, mut div, mut lost) = (0usize, 0usize, 0usize, 0usize);
    let (mut drops, mut dups, mut retransmits) = (0u64, 0u64, 0u64);
    for r in 0..args.runs {
        // Randomized inputs per simulation (paper: "new random inputs
        // were generated for each simulation").
        let p = ProblemSpec::new(args.n).with_eps(0.05).build(4000 + r as u64);
        let policy = StopPolicy {
            threshold,
            max_iters: args.divergence_iters,
            timeout_secs: timeout,
            check_every: 5,
            ..Default::default()
        };
        let cfg = SolveConfig {
            variant,
            backend: args.backend,
            clients,
            alpha,
            net: LatencyModel::lan(),
            seed: 100 + r as u64,
            faults: args.faults.clone(),
            recovery: args.recovery,
            ..Default::default()
        };
        let out = run_federated(&p, &cfg, policy, false);
        times.push(out.secs);
        if out.degraded {
            lost += 1;
        }
        match out.stop {
            StopReason::Converged => conv += 1,
            StopReason::Timeout => tout += 1,
            StopReason::MaxIters => div += 1,
            // Node-loss terminations: a crash injection emptied the run
            // or the recovery policy aborted on a struck peer.
            StopReason::Dead | StopReason::PeerLoss => div += 1,
        }
        drops += out.traffic.drops;
        dups += out.traffic.dups;
        retransmits += out.traffic.retransmits;
    }
    let pct = |k: usize| 100.0 * k as f64 / args.runs as f64;
    GridCell {
        avg_secs: Summary::of(&times).mean,
        pct_conv: pct(conv),
        pct_timeout: pct(tout),
        pct_div: pct(div),
        pct_lost: pct(lost),
        drops,
        dups,
        retransmits,
    }
}
