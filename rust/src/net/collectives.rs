//! MPI-style collectives on point-to-point sends.
//!
//! The sync protocols use blocking AllGather (Alg. 1) and Gather/Scatter
//! (Alg. 3); tags carry the protocol round so consecutive collectives
//! cannot cross. Each collective is "flat" (everyone ↔ everyone / root):
//! with ≤ 8 nodes the paper's clusters never justify tree algorithms,
//! and flat keeps per-node comm time directly interpretable.

use super::faults::Recovery;
use super::{Endpoint, TagKind};
use std::time::Duration;

/// AllGather: contribute `mine`, get back every node's part (indexed by
/// node id; `parts[me]` is a copy of `mine`).
pub fn allgather(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    mine: &[f64],
    iter: u64,
) -> Vec<Vec<f64>> {
    allgather_impl(ep, kind, round, None, mine, iter)
}

/// [`allgather`] whose slices ride the fabric's wire codec on `stream`
/// (`parts[me]` stays the sender's exact copy — only the wire hops are
/// coded). The scaling exchanges use this; control AllGathers stay on
/// the exact path.
pub fn allgather_coded(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    stream: u64,
    mine: &[f64],
    iter: u64,
) -> Vec<Vec<f64>> {
    allgather_impl(ep, kind, round, Some(stream), mine, iter)
}

fn allgather_impl(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    stream: Option<u64>,
    mine: &[f64],
    iter: u64,
) -> Vec<Vec<f64>> {
    let me = ep.id();
    let c = ep.nodes();
    for dst in 0..c {
        if dst != me {
            match stream {
                Some(s) => ep.send_coded(dst, kind, round, s, mine.to_vec(), iter),
                None => ep.send(dst, kind, round, mine.to_vec(), iter),
            }
        }
    }
    let mut parts: Vec<Vec<f64>> = vec![Vec::new(); c];
    parts[me] = mine.to_vec();
    for src in 0..c {
        if src != me {
            parts[src] = ep.recv_blocking(src, kind, round).payload;
        }
    }
    parts
}

/// Gather to `root`: returns `Some(parts)` at the root, `None` elsewhere.
pub fn gather(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    mine: &[f64],
    iter: u64,
) -> Option<Vec<Vec<f64>>> {
    gather_impl(ep, root, kind, round, None, mine, iter)
}

/// [`gather`] whose contributed slice rides the wire codec on `stream`.
pub fn gather_coded(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    stream: u64,
    mine: &[f64],
    iter: u64,
) -> Option<Vec<Vec<f64>>> {
    gather_impl(ep, root, kind, round, Some(stream), mine, iter)
}

fn gather_impl(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    stream: Option<u64>,
    mine: &[f64],
    iter: u64,
) -> Option<Vec<Vec<f64>>> {
    let me = ep.id();
    if me == root {
        let mut parts: Vec<Vec<f64>> = vec![Vec::new(); ep.nodes()];
        parts[me] = mine.to_vec();
        for src in 0..ep.nodes() {
            if src != root {
                parts[src] = ep.recv_blocking(src, kind, round).payload;
            }
        }
        Some(parts)
    } else {
        match stream {
            Some(s) => ep.send_coded(root, kind, round, s, mine.to_vec(), iter),
            None => ep.send(root, kind, round, mine.to_vec(), iter),
        }
        None
    }
}

/// Scatter from `root`: `full` (root only) is split into equal
/// `chunk`-sized slices by node id; every node returns its slice.
pub fn scatter(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    full: Option<&[f64]>,
    chunk: usize,
    iter: u64,
) -> Vec<f64> {
    let me = ep.id();
    if me == root {
        let full = full.expect("root must provide the full buffer");
        assert_eq!(full.len(), chunk * ep.nodes(), "scatter size mismatch");
        for dst in 0..ep.nodes() {
            if dst != root {
                ep.send(
                    dst,
                    kind,
                    round,
                    full[dst * chunk..(dst + 1) * chunk].to_vec(),
                    iter,
                );
            }
        }
        full[me * chunk..(me + 1) * chunk].to_vec()
    } else {
        ep.recv_blocking(root, kind, round).payload
    }
}

/// Broadcast from `root`.
pub fn bcast(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    data: Option<&[f64]>,
    iter: u64,
) -> Vec<f64> {
    bcast_impl(ep, root, kind, round, None, data, iter)
}

/// [`bcast`] whose data rides the wire codec on `stream`. Note the root
/// returns its own *exact* copy while peers receive the codec
/// reconstruction — callers for whom that asymmetry matters (the fleet
/// command broadcast does not: absorption is exact for any reference)
/// must use the exact [`bcast`].
pub fn bcast_coded(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    stream: u64,
    data: Option<&[f64]>,
    iter: u64,
) -> Vec<f64> {
    bcast_impl(ep, root, kind, round, Some(stream), data, iter)
}

fn bcast_impl(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    stream: Option<u64>,
    data: Option<&[f64]>,
    iter: u64,
) -> Vec<f64> {
    let me = ep.id();
    if me == root {
        let data = data.expect("root must provide data");
        for dst in 0..ep.nodes() {
            if dst != root {
                match stream {
                    Some(s) => ep.send_coded(dst, kind, round, s, data.to_vec(), iter),
                    None => ep.send(dst, kind, round, data.to_vec(), iter),
                }
            }
        }
        data.to_vec()
    } else {
        ep.recv_blocking(root, kind, round).payload
    }
}

/// Barrier: an empty AllGather on the control tag.
pub fn barrier(ep: &Endpoint, round: u64) {
    let _ = allgather(ep, TagKind::Ctl, round, &[], 0);
}

// ---------------------------------------------------------------------
// Resilient collectives (fault-plan runs only).
//
// Same flat shape as the exact collectives, but every receive is bounded
// by the recovery policy: `strikes` consecutive per-attempt timeouts on
// one peer declare it dead (`live[peer] = false`), the slot comes back
// `None`, and the collective completes over the survivors. Because the
// reliable streams always deliver (fast-forward ARQ), a missing frame
// can only mean the sender is gone — a strikeout is a death verdict,
// not packet loss. The sync protocols are lock-step, so every survivor
// waits on the same missing frame and converges on the same live set at
// the same round.

/// Receive `(src, kind, round)` under the recovery policy: up to
/// `strikes` attempts of `recv_timeout_secs` each; `None` = peer dead.
fn recv_striked(
    ep: &Endpoint,
    src: usize,
    kind: TagKind,
    round: u64,
    rec: &Recovery,
) -> Option<Vec<f64>> {
    let per_try = Duration::from_secs_f64(rec.recv_timeout_secs.max(1e-3));
    for _ in 0..rec.strikes.max(1) {
        if let Some(m) = ep.recv_timeout(src, kind, round, per_try) {
            return Some(m.payload);
        }
    }
    None
}

/// [`allgather`] bounded by the recovery policy: exchanges only with
/// peers still flagged in `live`, strikes silent peers dead, and
/// returns `None` in a dead peer's slot. `stream = Some(s)` rides the
/// wire codec like [`allgather_coded`].
#[allow(clippy::too_many_arguments)]
pub fn allgather_resilient(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    stream: Option<u64>,
    mine: &[f64],
    iter: u64,
    live: &mut [bool],
    rec: &Recovery,
) -> Vec<Option<Vec<f64>>> {
    let me = ep.id();
    let c = ep.nodes();
    assert_eq!(live.len(), c, "live mask must cover every node");
    for dst in 0..c {
        if dst != me && live[dst] {
            match stream {
                Some(s) => ep.send_coded(dst, kind, round, s, mine.to_vec(), iter),
                None => ep.send(dst, kind, round, mine.to_vec(), iter),
            }
        }
    }
    let mut parts: Vec<Option<Vec<f64>>> = vec![None; c];
    parts[me] = Some(mine.to_vec());
    for src in 0..c {
        if src != me && live[src] {
            match recv_striked(ep, src, kind, round, rec) {
                Some(p) => parts[src] = Some(p),
                None => live[src] = false,
            }
        }
    }
    parts
}

/// [`gather`] bounded by the recovery policy. The root strikes silent
/// peers dead and returns `Some(parts)` with `None` slots for them;
/// non-root nodes contribute (skipping a dead root) and return `None`.
#[allow(clippy::too_many_arguments)]
pub fn gather_resilient(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    stream: Option<u64>,
    mine: &[f64],
    iter: u64,
    live: &mut [bool],
    rec: &Recovery,
) -> Option<Vec<Option<Vec<f64>>>> {
    let me = ep.id();
    assert_eq!(live.len(), ep.nodes(), "live mask must cover every node");
    if me == root {
        let mut parts: Vec<Option<Vec<f64>>> = vec![None; ep.nodes()];
        parts[me] = Some(mine.to_vec());
        for src in 0..ep.nodes() {
            if src != root && live[src] {
                match recv_striked(ep, src, kind, round, rec) {
                    Some(p) => parts[src] = Some(p),
                    None => live[src] = false,
                }
            }
        }
        Some(parts)
    } else {
        if live[root] {
            match stream {
                Some(s) => ep.send_coded(root, kind, round, s, mine.to_vec(), iter),
                None => ep.send(root, kind, round, mine.to_vec(), iter),
            }
        }
        None
    }
}

/// [`bcast`] bounded by the recovery policy: the root sends to live
/// peers only; a non-root that strikes out on the root marks it dead
/// and gets `None` — for the star clients that is the server-loss
/// signal (`StopReason::PeerLoss`).
#[allow(clippy::too_many_arguments)]
pub fn bcast_resilient(
    ep: &Endpoint,
    root: usize,
    kind: TagKind,
    round: u64,
    stream: Option<u64>,
    data: Option<&[f64]>,
    iter: u64,
    live: &mut [bool],
    rec: &Recovery,
) -> Option<Vec<f64>> {
    let me = ep.id();
    assert_eq!(live.len(), ep.nodes(), "live mask must cover every node");
    if me == root {
        let data = data.expect("root must provide data");
        for dst in 0..ep.nodes() {
            if dst != root && live[dst] {
                match stream {
                    Some(s) => ep.send_coded(dst, kind, round, s, data.to_vec(), iter),
                    None => ep.send(dst, kind, round, data.to_vec(), iter),
                }
            }
        }
        Some(data.to_vec())
    } else if !live[root] {
        None
    } else {
        match recv_striked(ep, root, kind, round, rec) {
            Some(p) => Some(p),
            None => {
                live[root] = false;
                None
            }
        }
    }
}
