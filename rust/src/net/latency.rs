//! Per-message delivery-delay model.

use crate::rng::Rng;

/// Latency model: `delay = (base + bytes · per_byte) · jitter (· spike)`.
///
/// This is the α–β cost model of the paper (`base` = α per message,
/// `per_byte` = β): every message kind pays it — including the
/// fleet-absorption `Gref` probes/broadcasts, whose extra per-iteration
/// term therefore shows up honestly in the per-node comm buckets.
///
/// `jitter` is lognormal(0, sigma) — multiplicative, median 1 — matching
/// the heavy-tailed comm-time variability the paper reports (§IV-B4:
/// "the network's state at time of execution can have a non-deterministic
/// impact"); `spike_prob`/`spike_mult` model the rare pathological
/// transfers visible in their Fig 24 outlier.
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    pub base_secs: f64,
    pub per_byte_secs: f64,
    pub jitter_sigma: f64,
    pub spike_prob: f64,
    pub spike_mult: f64,
    /// Receiver-side decode cost per encoded byte: dequantizing a coded
    /// frame back into f64 lanes is *CPU* work the receiver pays at
    /// receive time, so the fabric prices it into the **comp** bucket
    /// (via [`crate::net::Endpoint::take_decode_secs`]) — without it the
    /// wire codec's byte savings would look free on the comm/comp split.
    pub decode_per_byte_secs: f64,
}

impl LatencyModel {
    /// No delay at all — unit tests and upper-bound runs.
    pub fn zero() -> Self {
        Self {
            base_secs: 0.0,
            per_byte_secs: 0.0,
            jitter_sigma: 0.0,
            spike_prob: 0.0,
            spike_mult: 1.0,
            decode_per_byte_secs: 0.0,
        }
    }

    /// Cluster-interconnect profile calibrated so the comm/comp balance
    /// at the default scaled problem sizes mirrors the paper's Fig 6:
    /// ~100 µs base per message + ~10 ns/byte (≈ 0.8 Gbit/s effective),
    /// 25% lognormal jitter, 1% chance of a 8× spike.
    pub fn lan() -> Self {
        Self {
            base_secs: 100e-6,
            per_byte_secs: 10e-9,
            jitter_sigma: 0.25,
            spike_prob: 0.01,
            spike_mult: 8.0,
            // ~4 GB/s single-core dequantization throughput.
            decode_per_byte_secs: 0.25e-9,
        }
    }

    /// Wide-area profile (geo-distributed offices, paper §V motivation):
    /// 5 ms base, ~50 ns/byte, heavier jitter and spikes.
    pub fn wan() -> Self {
        Self {
            base_secs: 5e-3,
            per_byte_secs: 50e-9,
            jitter_sigma: 0.5,
            spike_prob: 0.02,
            spike_mult: 10.0,
            // Same receiver CPUs as the LAN profile — decode cost is
            // compute, not network.
            decode_per_byte_secs: 0.25e-9,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "zero" => Some(Self::zero()),
            "lan" => Some(Self::lan()),
            "wan" => Some(Self::wan()),
            _ => None,
        }
    }

    /// Deterministic β-term seconds for a byte total on this profile
    /// (no jitter, no spikes): `bytes · per_byte`. What `perf-grid`
    /// reports next to the measured comm wall time, so the wire codec's
    /// compression factor is visible without jitter noise.
    pub fn beta_secs(&self, bytes: u64) -> f64 {
        bytes as f64 * self.per_byte_secs
    }

    /// Deterministic receiver-side decode seconds for one encoded frame
    /// of `bytes` — the CPU cost of dequantizing it back to f64 lanes.
    pub fn decode_secs(&self, bytes: usize) -> f64 {
        bytes as f64 * self.decode_per_byte_secs
    }

    /// Expected transmissions per reliable frame under an independent
    /// per-attempt drop probability `p` (geometric attempt count):
    /// `E[attempts] = 1 / (1 − p)`. The retransmit factor the README's
    /// α–β per-iteration cost table applies to lossy links — the fabric
    /// itself rolls actual attempt counts per frame
    /// (see [`crate::net::faults::FaultPlan::roll`]); this is the
    /// closed-form expectation those counts converge to.
    pub fn expected_attempts(p: f64) -> f64 {
        1.0 / (1.0 - p.clamp(0.0, 0.999_999))
    }

    /// Sample the delivery delay for a `bytes`-sized message.
    pub fn delay_secs(&self, bytes: usize, rng: &mut Rng) -> f64 {
        let mut d = self.base_secs + bytes as f64 * self.per_byte_secs;
        if self.jitter_sigma > 0.0 {
            d *= rng.lognormal(0.0, self.jitter_sigma);
        }
        if self.spike_prob > 0.0 && rng.uniform() < self.spike_prob {
            d *= self.spike_mult;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_model_is_zero() {
        let mut rng = Rng::seed_from(1);
        assert_eq!(LatencyModel::zero().delay_secs(1 << 20, &mut rng), 0.0);
    }

    #[test]
    fn delay_grows_with_bytes() {
        let mut rng = Rng::seed_from(2);
        let m = LatencyModel { jitter_sigma: 0.0, spike_prob: 0.0, ..LatencyModel::lan() };
        let small = m.delay_secs(8, &mut rng);
        let big = m.delay_secs(8 << 20, &mut rng);
        assert!(big > small * 10.0);
    }

    #[test]
    fn decode_cost_prices_encoded_bytes() {
        assert_eq!(LatencyModel::zero().decode_secs(1 << 20), 0.0);
        let lan = LatencyModel::lan();
        assert!(lan.decode_secs(1 << 20) > 0.0);
        assert!((lan.decode_secs(4096) - 4096.0 * lan.decode_per_byte_secs).abs() < 1e-18);
    }

    #[test]
    fn expected_attempts_is_geometric() {
        assert_eq!(LatencyModel::expected_attempts(0.0), 1.0);
        assert!((LatencyModel::expected_attempts(0.5) - 2.0).abs() < 1e-12);
        assert!((LatencyModel::expected_attempts(0.05) - 1.0 / 0.95).abs() < 1e-12);
        assert!(LatencyModel::expected_attempts(1.0).is_finite());
    }

    #[test]
    fn jitter_median_is_about_one() {
        let mut rng = Rng::seed_from(3);
        let m = LatencyModel {
            base_secs: 1.0,
            per_byte_secs: 0.0,
            jitter_sigma: 0.25,
            spike_prob: 0.0,
            spike_mult: 1.0,
            decode_per_byte_secs: 0.0,
        };
        let mut ds: Vec<f64> = (0..4001).map(|_| m.delay_secs(0, &mut rng)).collect();
        ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = ds[2000];
        assert!((median - 1.0).abs() < 0.05, "median {median}");
    }
}
