//! Simulated message-passing fabric — the MPI-cluster stand-in.
//!
//! The paper runs over mpi4py on a GPU cluster; the algorithms only
//! observe message *ordering, staleness (τ) and timing*, so the
//! substitution (DESIGN.md §3) is an in-process fabric that reproduces
//! exactly those observables:
//!
//! * [`LatencyModel`] — per-message delivery delay: base + per-byte +
//!   lognormal jitter + rare spikes (the "network state" effects of
//!   §IV-B4/§IV-C4). Delays are enforced by *delivery deadlines*; blocked
//!   receivers sleep until the deadline so comm time is real wall time.
//! * [`SimNet`]/[`Endpoint`] — per-node mailboxes with blocking
//!   (synchronous MPI `send/recv`), any-source streaming
//!   (`recv_any_blocking`, the slice-streaming exchange primitive) and
//!   latest-wins non-blocking (`Isend`/`Irecv`) receive modes.
//! * [`wire`] — the wire codec (`--wire-format f64|f32|deltaf32`):
//!   coded streams carry scale-headered reduced-precision / delta
//!   frames with sender-held error-feedback residuals; latency and the
//!   per-[`TagKind`] byte counters are priced on the encoded frames.
//! * [`collectives`] — AllGather / Gather / Scatter / Broadcast / Barrier
//!   built on point-to-point sends, like MPI's tree-free reference
//!   algorithms — plus `_coded` variants whose data slices ride the
//!   wire codec and `_resilient` variants bounded by the recovery
//!   policy (timeout → strikes → peer declared dead).
//! * [`faults`] — deterministic fault injection (`--drop-prob` etc.):
//!   per-link drop/dup/reorder/delay-spike schedules replayed exactly
//!   from a seed, node crash/straggler injections, and the
//!   retransmit/backoff parameters of the self-healing reliable
//!   streams. All recovery traffic is priced through [`LatencyModel`].
//! * [`DelayTracker`] — the τ staleness counter of §IV-C4 (Fig 15).

mod collectives;
mod fabric;
pub mod faults;
mod latency;
pub mod wire;

pub use collectives::{
    allgather, allgather_coded, allgather_resilient, barrier, bcast, bcast_coded,
    bcast_resilient, gather, gather_coded, gather_resilient, scatter,
};
pub use fabric::{Endpoint, Message, NetTraffic, SimNet, TagKind};
pub use faults::{FaultPlan, FrameFaults, LinkFault, LinkRtt, NodeFault, NodeLoss, Recovery};
pub use latency::LatencyModel;
pub use wire::WireFormat;

use std::sync::Mutex;

/// Records message staleness τ (receiver-side local-iteration lag) for
/// the delay study (Figs 15–17, Table V). Thread-safe: every client
/// thread pushes into the shared tracker.
#[derive(Debug, Default)]
pub struct DelayTracker {
    taus: Mutex<Vec<u64>>,
}

impl DelayTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one received message: sender iteration vs receiver iteration.
    pub fn record(&self, sender_iter: u64, receiver_iter: u64) {
        let tau = receiver_iter.saturating_sub(sender_iter);
        self.taus.lock().unwrap().push(tau);
    }

    pub fn taus(&self) -> Vec<u64> {
        self.taus.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.taus.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn point_to_point_delivers_payload() {
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 1));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let t = std::thread::spawn(move || {
            let msg = b.recv_blocking(0, TagKind::U, 0);
            msg.payload
        });
        a.send(1, TagKind::U, 0, vec![1.0, 2.0, 3.0], 0);
        assert_eq!(t.join().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn every_kind_has_a_counter() {
        // The derived kind list is the single source of truth: every
        // declared kind must have a unique in-range counter slot and
        // appear in the traffic snapshot — a kind added to the enum but
        // not to ALL/COUNT fails here at compile time or below.
        assert_eq!(TagKind::ALL.len(), TagKind::COUNT);
        let mut seen = vec![false; TagKind::COUNT];
        for k in TagKind::ALL {
            assert!(k.index() < TagKind::COUNT, "{} index out of range", k.name());
            assert!(!seen[k.index()], "duplicate counter index for {}", k.name());
            seen[k.index()] = true;
        }
        // One message per kind: each must land in its own bucket.
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 30));
        let a = net.endpoint(0);
        for k in TagKind::ALL {
            a.send(1, k, 0, vec![1.0], 0);
        }
        let t = net.traffic();
        assert_eq!(t.by_kind.len(), TagKind::COUNT);
        for k in TagKind::ALL {
            assert_eq!(net.kind_msgs(k), 1, "{} msg counter", k.name());
            assert!(t.bytes_of(k) > 0, "{} byte counter", k.name());
        }
        assert_eq!(t.total_msgs, TagKind::COUNT as u64);
    }

    #[test]
    fn latency_deadline_is_enforced() {
        let lat = LatencyModel { base_secs: 0.02, ..LatencyModel::zero() };
        let net = Arc::new(SimNet::new(2, lat, 2));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let t0 = std::time::Instant::now();
        a.send(1, TagKind::U, 0, vec![1.0], 0);
        let _ = b.recv_blocking(0, TagKind::U, 0);
        assert!(t0.elapsed().as_secs_f64() >= 0.018, "deadline ignored");
    }

    #[test]
    fn latest_wins_drains_backlog() {
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 3));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        for k in 0..5 {
            a.send(1, TagKind::V, 7, vec![k as f64], k);
        }
        // Allow zero-latency messages to land.
        std::thread::sleep(std::time::Duration::from_millis(5));
        let got = b.try_recv_latest(0, TagKind::V, 7).expect("message");
        assert_eq!(got.payload, vec![4.0]);
        assert_eq!(got.sent_iter, 4);
        // Backlog was drained.
        assert!(b.try_recv_latest(0, TagKind::V, 7).is_none());
    }

    #[test]
    fn tags_and_rounds_do_not_cross() {
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 4));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, TagKind::U, 1, vec![10.0], 0);
        a.send(1, TagKind::V, 1, vec![20.0], 0);
        a.send(1, TagKind::U, 2, vec![30.0], 0);
        let v = b.recv_blocking(0, TagKind::V, 1);
        let u2 = b.recv_blocking(0, TagKind::U, 2);
        let u1 = b.recv_blocking(0, TagKind::U, 1);
        assert_eq!(v.payload, vec![20.0]);
        assert_eq!(u2.payload, vec![30.0]);
        assert_eq!(u1.payload, vec![10.0]);
    }

    #[test]
    fn coded_sends_price_bytes_on_the_encoded_frame() {
        // Same payload, three fabrics: the f32/deltaf32 U-traffic must
        // land near half the f64 bytes, and the per-kind counters must
        // attribute it to the right bucket.
        let payload: Vec<f64> = (0..256).map(|i| (i as f64).sin()).collect();
        let mut totals = Vec::new();
        for fmt in [WireFormat::F64, WireFormat::F32, WireFormat::DeltaF32] {
            let net = Arc::new(SimNet::with_wire(2, LatencyModel::zero(), 1, fmt));
            let a = net.endpoint(0);
            let b = net.endpoint(1);
            a.send_coded(1, TagKind::U, 0, 0, payload.clone(), 0);
            let got = b.recv_blocking(0, TagKind::U, 0);
            // Reconstruction error bounded by the slice-range step.
            let err = got
                .payload
                .iter()
                .zip(&payload)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(err <= 1e-6, "{}: err {err}", fmt.name());
            assert_eq!(net.kind_msgs(TagKind::U), 1);
            assert_eq!(net.kind_bytes(TagKind::V), 0);
            assert_eq!(net.bytes_sent(), net.kind_bytes(TagKind::U));
            totals.push(net.bytes_sent());
        }
        assert!(totals[1] < totals[0] * 6 / 10, "f32 {} vs f64 {}", totals[1], totals[0]);
        assert_eq!(totals[1], totals[2], "deltaf32 frames are f32-width");
    }

    #[test]
    fn sparse_frames_carry_indices_and_price_below_dense() {
        // A 32-of-512 sparse frame must deliver its index vector intact
        // and cost strictly fewer bytes than the dense slice on every
        // wire format — that byte gap is the whole point of greedy
        // exchange.
        let dense_len = 512usize;
        let indices: Vec<u32> = (0..32u32).map(|i| i * 16).collect();
        let values: Vec<f64> = indices.iter().map(|&j| (j as f64 * 0.1).cos() * 5.0).collect();
        for fmt in [WireFormat::F64, WireFormat::F32, WireFormat::DeltaF32] {
            let net = Arc::new(SimNet::with_wire(2, LatencyModel::zero(), 31, fmt));
            let a = net.endpoint(0);
            let b = net.endpoint(1);
            a.send_sparse_coded(
                1,
                TagKind::SparseU,
                0,
                0,
                indices.clone(),
                values.clone(),
                dense_len,
                0,
            );
            let m = b.recv_blocking(0, TagKind::SparseU, 0);
            assert_eq!(m.indices, indices, "{}", fmt.name());
            let err =
                m.payload.iter().zip(&values).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
            assert!(err < 1e-5, "{}: err {err}", fmt.name());
            let sparse_bytes = net.kind_bytes(TagKind::SparseU);
            // Dense comparison frame on a fresh fabric of the same format.
            let dense_net = Arc::new(SimNet::with_wire(2, LatencyModel::zero(), 31, fmt));
            let da = dense_net.endpoint(0);
            da.send_coded(1, TagKind::U, 0, 0, vec![1.0; dense_len], 0);
            let dense_bytes = dense_net.kind_bytes(TagKind::U);
            assert!(
                sparse_bytes < dense_bytes,
                "{}: sparse {sparse_bytes} !< dense {dense_bytes}",
                fmt.name()
            );
        }
    }

    #[test]
    fn try_recv_all_returns_every_frame_oldest_first() {
        // Unlike try_recv_latest, the sparse drain must hand back every
        // deliverable frame (older frames carry coordinates newer ones
        // may not), ordered by sent_iter so re-selected coordinates
        // scatter to their newest value last.
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 32));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send_sparse_coded(1, TagKind::SparseV, 4, 0, vec![0, 3], vec![1.0, 2.0], 8, 10);
        a.send_sparse_coded(1, TagKind::SparseV, 4, 0, vec![5], vec![3.0], 8, 11);
        a.send_sparse_coded(1, TagKind::SparseV, 4, 0, vec![0, 7], vec![4.0, 5.0], 8, 12);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let all = b.try_recv_all(0, TagKind::SparseV, 4);
        assert_eq!(all.len(), 3);
        assert_eq!(
            all.iter().map(|m| m.sent_iter).collect::<Vec<_>>(),
            vec![10, 11, 12]
        );
        assert_eq!(all[1].indices, vec![5]);
        assert_eq!(all[1].payload, vec![3.0]);
        // Drained.
        assert!(b.try_recv_all(0, TagKind::SparseV, 4).is_empty());
        // Scatter oldest-first leaves coordinate 0 at its newest value.
        let mut slice = [0.0f64; 8];
        for m in &all {
            for (k, &j) in m.indices.iter().enumerate() {
                slice[j as usize] = m.payload[k];
            }
        }
        assert_eq!(slice, [4.0, 0.0, 0.0, 2.0, 0.0, 3.0, 0.0, 5.0]);
    }

    #[test]
    fn lost_sparse_latest_wins_frames_reprime_their_lanes() {
        use faults::{FaultPlan, LinkFault};
        // Lossy latest-wins sparse DeltaF32 stream: every frame that IS
        // delivered must reconstruct near-exactly even though dropped
        // frames advanced the sender's reference — the sparse codec
        // re-keys on loss, so survivors are absolute.
        let plan = FaultPlan {
            seed: 33,
            default_link: LinkFault { drop_prob: 0.4, ..LinkFault::none() },
            ..FaultPlan::none()
        };
        let net = Arc::new(
            SimNet::with_wire(2, LatencyModel::zero(), 33, WireFormat::DeltaF32)
                .with_faults(plan),
        );
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let indices: Vec<u32> = (0..16u32).collect();
        let mut delivered = 0;
        for round in 0..60u64 {
            let v: Vec<f64> =
                indices.iter().map(|&j| (j as f64 * 0.4).sin() + round as f64 * 0.9).collect();
            a.send_sparse_coded_latest(
                1,
                TagKind::SparseU,
                6,
                0,
                indices.clone(),
                v.clone(),
                64,
                round,
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
            for m in b.try_recv_all(0, TagKind::SparseU, 6) {
                delivered += 1;
                let sent: Vec<f64> = indices
                    .iter()
                    .map(|&j| (j as f64 * 0.4).sin() + m.sent_iter as f64 * 0.9)
                    .collect();
                let err =
                    m.payload.iter().zip(&sent).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                assert!(err < 1e-3, "iter {}: err {err}", m.sent_iter);
            }
        }
        assert!(delivered > 10, "only {delivered}/60 delivered");
        assert!(net.traffic().drops > 0);
    }

    #[test]
    fn recv_any_consumes_slices_in_delivery_order() {
        // Peer 1's frame is delayed well past peer 2's: the streaming
        // receive must hand back 2 first, then 1 — not block on the
        // numerically first source.
        let net = Arc::new(SimNet::new(3, LatencyModel::zero(), 8));
        let ep0 = net.endpoint(0);
        let ep1 = net.endpoint(1);
        let ep2 = net.endpoint(2);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            ep1.send(0, TagKind::U, 5, vec![1.0], 0);
        });
        ep2.send(0, TagKind::U, 5, vec![2.0], 0);
        let mut pending = vec![false, true, true];
        let first = ep0.recv_any_blocking(&pending, TagKind::U, 5);
        assert_eq!(first.src, 2);
        pending[first.src] = false;
        let second = ep0.recv_any_blocking(&pending, TagKind::U, 5);
        assert_eq!(second.src, 1);
        t.join().unwrap();
    }

    #[test]
    fn wait_traffic_wakes_on_arrival_and_caps_when_quiet() {
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 9));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let seen = b.inbox_seq();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            a.send(1, TagKind::U, 0, vec![1.0], 0);
        });
        let t0 = std::time::Instant::now();
        let seq = b.wait_traffic(seen, std::time::Duration::from_secs(2));
        assert_ne!(seq, seen, "arrival must move the counter");
        assert!(t0.elapsed().as_secs_f64() < 1.0, "woke by notify, not cap");
        t.join().unwrap();
        // Nothing new — and the already-deliverable queued message must
        // not spin the wait (entry-time deadline filter): the cap bounds
        // the park.
        let seen = b.inbox_seq();
        let t0 = std::time::Instant::now();
        let seq = b.wait_traffic(seen, std::time::Duration::from_millis(10));
        assert_eq!(seq, seen);
        assert!(t0.elapsed().as_secs_f64() >= 0.005, "cap respected");
    }

    #[test]
    fn wait_traffic_wakes_when_a_deadline_passes() {
        let lat = LatencyModel { base_secs: 0.03, ..LatencyModel::zero() };
        let net = Arc::new(SimNet::new(2, lat, 10));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, TagKind::U, 0, vec![1.0], 0);
        let seen = b.inbox_seq();
        let t0 = std::time::Instant::now();
        let _ = b.wait_traffic(seen, std::time::Duration::from_secs(2));
        let dt = t0.elapsed().as_secs_f64();
        assert!((0.02..1.0).contains(&dt), "woke at the delivery deadline, got {dt}");
    }

    #[test]
    fn decode_cost_lands_in_the_receiver_bucket() {
        let lat = LatencyModel { decode_per_byte_secs: 1e-6, ..LatencyModel::zero() };
        let net = Arc::new(SimNet::with_wire(2, lat, 11, WireFormat::F32));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        assert_eq!(b.take_decode_secs(), 0.0);
        a.send_coded(1, TagKind::U, 0, 0, vec![1.0; 256], 0);
        let _ = b.recv_blocking(0, TagKind::U, 0);
        let d = b.take_decode_secs();
        let bytes = net.bytes_sent() as f64;
        assert!(d > 0.0, "decode cost accumulated");
        assert!((d - bytes * 1e-6).abs() < bytes * 1e-8, "d {d} vs bytes {bytes}");
        // Drained: a second take returns zero.
        assert_eq!(b.take_decode_secs(), 0.0);
    }

    #[test]
    fn keyframe_cadence_rides_the_fabric() {
        let net = Arc::new(
            SimNet::with_wire(2, LatencyModel::zero(), 12, WireFormat::DeltaF32)
                .with_keyframe_every(2),
        );
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        for round in 0..6u64 {
            let v: Vec<f64> = (0..32).map(|i| (i as f64) + round as f64 * 1e-3).collect();
            a.send_coded(1, TagKind::U, round, 0, v.clone(), round);
            let got = b.recv_blocking(0, TagKind::U, round);
            let err =
                got.payload.iter().zip(&v).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
            assert!(err < 1e-4, "round {round}: err {err}");
        }
    }

    #[test]
    fn delay_tracker_clamps_at_zero() {
        let d = DelayTracker::new();
        d.record(5, 9);
        d.record(9, 5); // receiver behind sender → 0
        assert_eq!(d.taus(), vec![4, 0]);
    }

    #[test]
    fn allgather_assembles_all_parts() {
        let net = Arc::new(SimNet::new(3, LatencyModel::zero(), 5));
        let mut handles = Vec::new();
        for me in 0..3 {
            let net = net.clone();
            handles.push(std::thread::spawn(move || {
                let ep = net.endpoint(me);
                let mine = vec![me as f64; 2];
                let parts = allgather(&ep, TagKind::U, 0, &mine, 0);
                parts.concat()
            }));
        }
        for h in handles {
            assert_eq!(
                h.join().unwrap(),
                vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]
            );
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let net = Arc::new(SimNet::new(4, LatencyModel::zero(), 6));
        let mut handles = Vec::new();
        for me in 0..4 {
            let net = net.clone();
            handles.push(std::thread::spawn(move || {
                let ep = net.endpoint(me);
                // gather slices to root 0
                let mine = vec![(me * 10) as f64];
                let gathered = gather(&ep, 0, TagKind::U, 0, &mine, 0);
                // root doubles and scatters back
                let out = if me == 0 {
                    let full: Vec<f64> =
                        gathered.unwrap().concat().iter().map(|x| x * 2.0).collect();
                    scatter(&ep, 0, TagKind::V, 0, Some(&full), 1, 0)
                } else {
                    scatter(&ep, 0, TagKind::V, 0, None, 1, 0)
                };
                out[0]
            }));
        }
        let results: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(results, vec![0.0, 20.0, 40.0, 60.0]);
    }

    #[test]
    fn reliable_streams_heal_drops_and_price_the_recovery() {
        use faults::{FaultPlan, LinkFault};
        let rounds = 200u64;
        let run = |plan: FaultPlan| {
            let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 21).with_faults(plan));
            let a = net.endpoint(0);
            let b = net.endpoint(1);
            for k in 0..rounds {
                a.send(1, TagKind::U, k, vec![k as f64, -(k as f64)], k);
            }
            for k in 0..rounds {
                let m = b.recv_blocking(0, TagKind::U, k);
                assert_eq!(m.payload, vec![k as f64, -(k as f64)], "round {k}");
            }
            net.traffic()
        };
        let clean = run(FaultPlan::none());
        let lossy = run(FaultPlan {
            seed: 5,
            default_link: LinkFault { drop_prob: 0.15, ..LinkFault::none() },
            ..FaultPlan::none()
        });
        assert_eq!(clean.drops, 0);
        assert_eq!(clean.retransmits, 0);
        assert!(lossy.drops > 0, "schedule must actually drop");
        assert_eq!(
            lossy.retransmits, lossy.drops,
            "every reliable drop is a priced retransmission"
        );
        // Recovery cost lands in the byte/message counters.
        assert!(lossy.total_bytes > clean.total_bytes);
        assert!(lossy.total_msgs > clean.total_msgs);
    }

    #[test]
    fn duplicate_copies_are_swept_on_take() {
        use faults::{FaultPlan, LinkFault};
        let plan = FaultPlan {
            seed: 1,
            default_link: LinkFault { dup_prob: 1.0, ..LinkFault::none() },
            ..FaultPlan::none()
        };
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 22).with_faults(plan));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, TagKind::U, 0, vec![7.0], 0);
        assert_eq!(b.pending(), 2, "original + duplicate queued");
        let m = b.recv_blocking(0, TagKind::U, 0);
        assert_eq!(m.payload, vec![7.0]);
        assert_eq!(b.pending(), 0, "same-seq sibling swept on take");
        assert_eq!(net.traffic().dups, 1);
    }

    #[test]
    fn latest_wins_frames_are_lost_not_retransmitted() {
        use faults::{FaultPlan, LinkFault};
        let mut plan = FaultPlan { seed: 2, ..FaultPlan::none() };
        plan.links
            .insert((0, 1), LinkFault { drop_prob: 1.0, ..LinkFault::none() });
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 23).with_faults(plan));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send_coded_latest(1, TagKind::V, 3, 0, vec![1.0, 2.0], 0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(b.try_recv_latest(0, TagKind::V, 3).is_none(), "blackholed");
        let t = net.traffic();
        assert!(t.drops > 0);
        assert_eq!(t.retransmits, 0, "latest-wins never retransmits");
    }

    #[test]
    fn deltaf32_rekeys_after_latest_wins_loss() {
        use faults::{FaultPlan, LinkFault};
        // A lossy latest-wins DeltaF32 stream: whenever a frame IS
        // delivered its reconstruction must be near-exact, because the
        // sender re-keys after every lost frame (without the rekey the
        // receiver would difference against frames it never saw).
        let plan = FaultPlan {
            seed: 17,
            default_link: LinkFault { drop_prob: 0.4, ..LinkFault::none() },
            ..FaultPlan::none()
        };
        let net = Arc::new(
            SimNet::with_wire(2, LatencyModel::zero(), 24, WireFormat::DeltaF32)
                .with_faults(plan),
        );
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let mut delivered = 0;
        for round in 0..60u64 {
            let v: Vec<f64> = (0..64)
                .map(|i| (i as f64 * 0.37).sin() * 3.0 + round as f64 * 0.71)
                .collect();
            a.send_coded_latest(1, TagKind::U, 9, 0, v.clone(), round);
            std::thread::sleep(std::time::Duration::from_millis(1));
            if let Some(m) = b.try_recv_latest(0, TagKind::U, 9) {
                delivered += 1;
                let err =
                    m.payload.iter().zip(&v).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                assert!(err < 1e-3, "round {round}: reconstruction err {err}");
            }
        }
        assert!(delivered > 10, "only {delivered}/60 delivered");
        assert!(net.traffic().drops > 0);
    }

    #[test]
    fn fault_schedule_is_identical_across_runs() {
        use faults::{FaultPlan, LinkFault};
        let plan = FaultPlan {
            seed: 77,
            default_link: LinkFault {
                drop_prob: 0.2,
                dup_prob: 0.1,
                reorder_prob: 0.1,
                delay_spike: (0.1, 4.0),
            },
            ..FaultPlan::none()
        };
        let run = |plan: FaultPlan| {
            let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 25).with_faults(plan));
            let a = net.endpoint(0);
            let b = net.endpoint(1);
            let mut seqs = Vec::new();
            for k in 0..150u64 {
                a.send(1, TagKind::U, k, vec![k as f64], k);
                seqs.push(b.recv_blocking(0, TagKind::U, k).seq);
            }
            (seqs, net.traffic())
        };
        let (seq_a, ta) = run(plan.clone());
        let (seq_b, tb) = run(plan);
        assert_eq!(seq_a, seq_b, "link sequence numbering replays exactly");
        assert_eq!((ta.drops, ta.dups, ta.reorders, ta.retransmits, ta.spikes), (
            tb.drops, tb.dups, tb.reorders, tb.retransmits, tb.spikes
        ));
        assert!(ta.drops > 0 && ta.dups > 0 && ta.reorders > 0 && ta.spikes > 0);
    }

    #[test]
    fn recv_timeout_bounds_the_wait() {
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 26));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let t0 = std::time::Instant::now();
        let miss = b.recv_timeout(0, TagKind::U, 0, std::time::Duration::from_millis(30));
        assert!(miss.is_none());
        let dt = t0.elapsed().as_secs_f64();
        assert!((0.02..1.0).contains(&dt), "timed out near the deadline, got {dt}");
        a.send(1, TagKind::U, 0, vec![4.0], 0);
        let hit = b.recv_timeout(0, TagKind::U, 0, std::time::Duration::from_secs(2));
        assert_eq!(hit.expect("delivered").payload, vec![4.0]);
    }

    #[test]
    fn resilient_allgather_strikes_a_silent_peer_dead() {
        use faults::Recovery;
        // Node 2 never shows up: 0 and 1 must exchange, mark 2 dead, and
        // agree on the survivor parts — without hanging.
        let net = Arc::new(SimNet::new(3, LatencyModel::zero(), 27));
        let rec = Recovery { recv_timeout_secs: 0.05, ..Recovery::default() };
        let mut handles = Vec::new();
        for me in 0..2 {
            let net = net.clone();
            handles.push(std::thread::spawn(move || {
                let ep = net.endpoint(me);
                let mut live = vec![true; 3];
                let parts = allgather_resilient(
                    &ep,
                    TagKind::U,
                    4,
                    None,
                    &[me as f64],
                    0,
                    &mut live,
                    &rec,
                );
                (live, parts)
            }));
        }
        for h in handles {
            let (live, parts) = h.join().unwrap();
            assert_eq!(live, vec![true, true, false]);
            assert_eq!(parts[0].as_deref(), Some(&[0.0][..]));
            assert_eq!(parts[1].as_deref(), Some(&[1.0][..]));
            assert!(parts[2].is_none());
        }
    }

    #[test]
    fn resilient_bcast_reports_a_dead_root() {
        use faults::Recovery;
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 28));
        let ep = net.endpoint(1);
        let rec = Recovery { recv_timeout_secs: 0.02, strikes: 2, ..Recovery::default() };
        let mut live = vec![true; 2];
        let got = bcast_resilient(&ep, 0, TagKind::Ctl, 9, None, None, 0, &mut live, &rec);
        assert!(got.is_none());
        assert!(!live[0], "silent root declared dead");
        // A later call against the known-dead root returns immediately.
        let t0 = std::time::Instant::now();
        let again = bcast_resilient(&ep, 0, TagKind::Ctl, 10, None, None, 0, &mut live, &rec);
        assert!(again.is_none());
        assert!(t0.elapsed().as_secs_f64() < 0.02, "no re-strike on a dead peer");
    }

    #[test]
    fn stall_watchdog_dumps_the_inbox_instead_of_hanging() {
        let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 29));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        // Something unrelated is queued, so the dump has content.
        a.send(1, TagKind::V, 3, vec![1.0], 5);
        std::env::set_var("FEDSINK_STALL_SECS", "0.3");
        let t = std::thread::spawn(move || {
            // Nothing will ever match (kind=U, tag=0): must panic, not hang.
            let _ = b.recv_blocking(0, TagKind::U, 0);
        });
        let joined = t.join();
        std::env::remove_var("FEDSINK_STALL_SECS");
        let err = joined.expect_err("watchdog must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic".to_string());
        assert!(msg.contains("FEDSINK_STALL_SECS"), "got: {msg}");
        assert!(msg.contains("kind=V tag=3"), "inbox dump missing: {msg}");
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let net = Arc::new(SimNet::new(3, LatencyModel::zero(), 7));
        let counter = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for me in 0..3 {
            let net = net.clone();
            let counter = counter.clone();
            handles.push(std::thread::spawn(move || {
                let ep = net.endpoint(me);
                counter.fetch_add(1, Ordering::SeqCst);
                barrier(&ep, 99);
                // After the barrier, everyone must have incremented.
                counter.load(Ordering::SeqCst)
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
    }
}
