//! Wire codec for the scaling-slice exchange.
//!
//! The paper's per-iteration communication cost is `α + β·bytes` per
//! message; as the node count and histogram count grow, the `β` term on
//! the exchanged scaling slices dominates. On the dual-absorbed hybrid
//! schedule the exchanged log-scalings move *slowly* between rounds
//! (that is the whole premise of the absorption engine), which is
//! exactly the regime where reduced-precision and delta wire formats
//! pay: the same slice can ride half the bytes with an error far below
//! the solver tolerance.
//!
//! Formats:
//!
//! * [`WireFormat::F64`] — exact 8-byte lanes (the PR-4 baseline wire).
//! * [`WireFormat::F32`] — each frame carries a per-slice scale header
//!   `(offset, scale)` and 4-byte lanes of the normalized values
//!   `(v − offset)/scale ∈ [−1, 1]`; the header centers the slice so
//!   the quantization step is `scale·2⁻²⁴` of the slice *range*, not of
//!   the (possibly huge, e.g. duals/ε) absolute magnitude.
//! * [`WireFormat::DeltaF32`] — the first frame of a stream is an
//!   absolute F32 keyframe; every later frame encodes the *delta*
//!   against the receiver's current reconstruction in the same
//!   scale-headered 4-byte lanes. Because consecutive Sinkhorn slices
//!   differ by the (contracting) iteration step, the delta range — and
//!   with it the quantization step — shrinks as the solve converges, so
//!   DeltaF32 reaches tight thresholds F32 cannot.
//!
//! Both lossy formats carry a **sender-held error-feedback residual**:
//! the quantization error of frame `t` is added to the values of frame
//! `t+1` before encoding, so the error never accumulates across rounds
//! (the standard error-feedback compressor of decentralized consensus
//! methods; PAPERS.md 2509.14521). The reconstruction error at any
//! round is bounded by the carried residual plus one quantization step
//! of that round's frame — so it is flat over time, never accumulating,
//! and for DeltaF32 it drops to delta-sized steps one round after the
//! keyframe (whose f32-sized residual is flushed by the first delta
//! frame). Pinned by the
//! `error_feedback_bounds_reconstruction_over_many_rounds` test.
//!
//! The simulated fabric applies the codec at *send* time: the encoded
//! frame size prices the delivery deadline and the byte counters, and
//! the enqueued payload is exactly the decoder's reconstruction (the
//! sender must track it anyway for the residual, and frames of a stream
//! are decoded in send order, so the reconstruction is identical to
//! what a stateful receiver-side decoder would produce). A frame
//! containing non-finite values (±∞ scalings from fully masked rows)
//! falls back to an exact F64 frame — lossy-coding an infinity is
//! meaningless and the fallback keeps every protocol edge case exact.

/// Frame encoding for coded streams (`--wire-format`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// Exact 8-byte lanes.
    F64,
    /// Per-slice scale header + 4-byte normalized lanes.
    F32,
    /// F32 keyframe, then scale-headered 4-byte *delta* frames against
    /// the receiver's reconstruction.
    DeltaF32,
}

impl WireFormat {
    pub fn parse(s: &str) -> Option<WireFormat> {
        match s {
            "f64" => Some(WireFormat::F64),
            "f32" => Some(WireFormat::F32),
            "deltaf32" | "delta-f32" => Some(WireFormat::DeltaF32),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireFormat::F64 => "f64",
            WireFormat::F32 => "f32",
            WireFormat::DeltaF32 => "deltaf32",
        }
    }

    /// Whether frames of this format quantize (lossy lanes + residual).
    pub fn is_lossy(self) -> bool {
        self != WireFormat::F64
    }
}

/// Per-slice scale header of the 4-byte formats: `(offset, scale)` as
/// two f64 lanes.
pub const SLICE_SCALE_HEADER_BYTES: usize = 16;

/// Encoded size of an exact frame (`len` f64 lanes).
pub fn f64_frame_bytes(len: usize) -> usize {
    8 * len
}

/// Encoded size of a scale-headered 4-byte frame.
pub fn f32_frame_bytes(len: usize) -> usize {
    SLICE_SCALE_HEADER_BYTES + 4 * len
}

/// One encoded frame: the wire size it pays for, and the receiver-side
/// reconstruction it delivers.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub bytes: usize,
    pub payload: Vec<f64>,
}

/// LEB128 varint width of one value.
fn varint_bytes(v: u64) -> usize {
    (64 - v.max(1).leading_zeros() as usize).div_ceil(7)
}

/// Encoded size of a sparse frame's index block: a varint count plus
/// delta-varint-packed sorted indices (first absolute, then gaps).
/// Consecutive or clustered top-k rows pack near 1 byte per index —
/// the greedy exchange's index overhead rides this, priced by the same
/// α–β latency model as the value lanes.
pub fn sparse_index_bytes(indices: &[u32]) -> usize {
    let mut bytes = varint_bytes(indices.len() as u64);
    let mut prev = 0u64;
    for (i, &idx) in indices.iter().enumerate() {
        let gap = if i == 0 { idx as u64 } else { (idx as u64).saturating_sub(prev) };
        bytes += varint_bytes(gap);
        prev = idx as u64;
    }
    bytes
}

/// Sender-held per-stream codec state. One instance per
/// `(destination, kind, stream)` — streams with unrelated content must
/// not share a codec, or DeltaF32 would difference across them.
#[derive(Debug)]
pub struct StreamCodec {
    format: WireFormat,
    /// Forced-keyframe cadence for DeltaF32 (`--wire-keyframe-every`):
    /// every `K`-th frame is sent as an absolute keyframe, bounding how
    /// long a receiver joining (or recovering) mid-stream must wait for
    /// a self-contained frame. 0 disables the cadence (keyframes only
    /// on priming, length changes and non-finite fallbacks).
    keyframe_every: usize,
    /// Frames encoded so far on this stream (drives the cadence).
    frames: u64,
    /// Receiver's current reconstruction (DeltaF32 reference; empty
    /// until the keyframe primes the stream or after a length change).
    reference: Vec<f64>,
    /// Error-feedback residual: quantization error of the last frame,
    /// folded into the next frame's target before encoding.
    residual: Vec<f64>,
}

impl StreamCodec {
    pub fn new(format: WireFormat) -> Self {
        Self::with_keyframe_every(format, 0)
    }

    /// Codec with a forced-keyframe cadence (DeltaF32 only; the other
    /// formats have no inter-frame state to re-key).
    pub fn with_keyframe_every(format: WireFormat, keyframe_every: usize) -> Self {
        Self {
            format,
            keyframe_every,
            frames: 0,
            reference: Vec::new(),
            residual: Vec::new(),
        }
    }

    /// Force the next frame to an absolute keyframe: the fault layer
    /// calls this when a latest-wins frame is lost in flight, so the
    /// receiver's reconstruction can never diverge from the sender's
    /// reference. Clears the error-feedback residual too — it tracked a
    /// reconstruction the receiver never saw.
    pub fn rekey(&mut self) {
        self.reference.clear();
        self.residual.clear();
    }

    /// Encode one frame, advancing the stream state. Takes the values by
    /// value so the exact paths deliver them without a copy.
    pub fn encode(&mut self, values: Vec<f64>) -> Encoded {
        let idx = self.frames;
        self.frames += 1;
        match self.format {
            WireFormat::F64 => {
                Encoded { bytes: f64_frame_bytes(values.len()), payload: values }
            }
            _ if !values.iter().all(|v| v.is_finite()) => {
                // Non-finite lanes (±∞ scalings): exact fallback frame,
                // and the stream re-primes on the next finite frame.
                self.reference.clear();
                self.residual.clear();
                Encoded { bytes: f64_frame_bytes(values.len()), payload: values }
            }
            WireFormat::F32 => self.encode_f32(values),
            WireFormat::DeltaF32 => {
                if self.keyframe_every > 0 && idx > 0 && idx % self.keyframe_every as u64 == 0 {
                    // Cadence hit: drop the reference so `encode_delta`
                    // takes its existing keyframe (re-prime) path.
                    self.reference.clear();
                }
                self.encode_delta(values)
            }
        }
    }

    /// Absolute scale-headered 4-byte frame with error feedback.
    fn encode_f32(&mut self, values: Vec<f64>) -> Encoded {
        let n = values.len();
        if self.residual.len() != n {
            self.residual = vec![0.0; n];
        }
        // Error feedback: quantize value + carried residual.
        let mut payload = values;
        for (v, r) in payload.iter_mut().zip(&self.residual) {
            *v += r;
        }
        let (offset, scale) = offset_scale(&payload);
        for (v, r) in payload.iter_mut().zip(self.residual.iter_mut()) {
            let q = quantize(*v, offset, scale);
            *r = *v - q;
            *v = q;
        }
        Encoded { bytes: f32_frame_bytes(n), payload }
    }

    /// Delta frame against the receiver's reconstruction; falls back to
    /// an absolute keyframe whenever the stream is unprimed (first
    /// frame, length change, post-fallback).
    fn encode_delta(&mut self, values: Vec<f64>) -> Encoded {
        let n = values.len();
        if self.reference.len() != n {
            self.residual.clear();
            let enc = self.encode_f32(values);
            self.reference = enc.payload.clone();
            return enc;
        }
        debug_assert_eq!(self.residual.len(), n);
        // target = value + residual; delta = target − reference.
        let mut delta = values;
        for ((d, r), g) in delta.iter_mut().zip(&self.residual).zip(&self.reference) {
            *d += r - g;
        }
        let (offset, scale) = offset_scale(&delta);
        for ((d, g), r) in delta
            .iter_mut()
            .zip(self.reference.iter_mut())
            .zip(self.residual.iter_mut())
        {
            let qd = quantize(*d, offset, scale);
            let target = *g + *d;
            *g += qd;
            *r = target - *g;
            *d = *g;
        }
        // `delta` now holds the new reconstruction.
        Encoded { bytes: f32_frame_bytes(n), payload: delta }
    }
}

/// Sender-held codec state of one **sparse** coordinate-update stream
/// (`--exchange greedy`): reference/residual/primed arrays indexed by
/// *dense coordinate*, not frame position, because consecutive frames
/// select different coordinate subsets.
///
/// Every frame delivers **absolute** reconstructions at its selected
/// coordinates (even DeltaF32 frames: the payload is the updated
/// reference, not the delta), so a receiver just scatters — and a
/// superseded latest-wins frame that carried coordinates the newest
/// frame lacks leaves only a *stale* value behind, never a diverging
/// one. Error feedback is per-coordinate: the residual of coordinate
/// `j`'s last encoding is folded in the next time `j` is selected.
///
/// DeltaF32 frames difference against the per-coordinate reference;
/// a frame containing any unprimed lane (first selection, post-rekey,
/// keyframe cadence) is sent absolute (F32-coded) and primes its
/// lanes. [`SparseStreamCodec::rekey`] clears every primed bit and the
/// residuals, so after a latest-wins loss the next frame touching any
/// coordinate re-sends it absolutely — the receiver snaps to the
/// correct value and reconstruction never diverges.
#[derive(Debug)]
pub struct SparseStreamCodec {
    format: WireFormat,
    /// Forced-keyframe cadence (`--wire-keyframe-every`): every `K`-th
    /// frame clears the primed bitmap, so each coordinate's next
    /// selection is absolute. 0 = off.
    keyframe_every: usize,
    frames: u64,
    /// Receiver's reconstruction per dense coordinate (valid where
    /// `primed`).
    reference: Vec<f64>,
    /// Per-coordinate error-feedback residual.
    residual: Vec<f64>,
    /// Whether the receiver holds a reconstruction of each coordinate.
    primed: Vec<bool>,
}

impl SparseStreamCodec {
    pub fn new(format: WireFormat) -> Self {
        Self::with_keyframe_every(format, 0)
    }

    pub fn with_keyframe_every(format: WireFormat, keyframe_every: usize) -> Self {
        Self {
            format,
            keyframe_every,
            frames: 0,
            reference: Vec::new(),
            residual: Vec::new(),
            primed: Vec::new(),
        }
    }

    /// Latest-wins loss: the receiver never saw the lost frame, so drop
    /// every primed bit (next selection of any coordinate is absolute)
    /// and the residuals (they track reconstructions the receiver never
    /// confirmed).
    pub fn rekey(&mut self) {
        self.primed.iter_mut().for_each(|p| *p = false);
        self.residual.iter_mut().for_each(|r| *r = 0.0);
    }

    /// Encode one sparse frame: `values[i]` is the new value at dense
    /// coordinate `indices[i]` of a `dense_len`-wide slice. Returns the
    /// value-lane frame size (the caller adds
    /// [`sparse_index_bytes`]) and the receiver-side reconstruction.
    pub fn encode(&mut self, indices: &[u32], values: Vec<f64>, dense_len: usize) -> Encoded {
        debug_assert_eq!(indices.len(), values.len());
        if self.reference.len() != dense_len {
            // First frame or slice-shape change: full re-prime.
            self.reference = vec![0.0; dense_len];
            self.residual = vec![0.0; dense_len];
            self.primed = vec![false; dense_len];
        }
        let idx = self.frames;
        self.frames += 1;
        let k = values.len();
        match self.format {
            WireFormat::F64 => Encoded { bytes: f64_frame_bytes(k), payload: values },
            _ if !values.iter().all(|v| v.is_finite()) => {
                // Exact fallback; the touched lanes stay coherent (the
                // receiver gets the exact values) but re-prime anyway —
                // differencing against ±∞ is meaningless.
                for &j in indices {
                    self.primed[j as usize] = false;
                    self.residual[j as usize] = 0.0;
                }
                Encoded { bytes: f64_frame_bytes(k), payload: values }
            }
            WireFormat::F32 => self.encode_absolute(indices, values),
            WireFormat::DeltaF32 => {
                if self.keyframe_every > 0 && idx > 0 && idx % self.keyframe_every as u64 == 0 {
                    self.primed.iter_mut().for_each(|p| *p = false);
                }
                if indices.iter().any(|&j| !self.primed[j as usize]) {
                    self.encode_absolute(indices, values)
                } else {
                    self.encode_delta(indices, values)
                }
            }
        }
    }

    /// Absolute scale-headered 4-byte lanes over the selected subset,
    /// with per-coordinate error feedback; primes every touched lane.
    fn encode_absolute(&mut self, indices: &[u32], values: Vec<f64>) -> Encoded {
        let k = values.len();
        let mut payload = values;
        for (v, &j) in payload.iter_mut().zip(indices) {
            *v += self.residual[j as usize];
        }
        let (offset, scale) = offset_scale(&payload);
        for (v, &j) in payload.iter_mut().zip(indices) {
            let j = j as usize;
            let q = quantize(*v, offset, scale);
            self.residual[j] = *v - q;
            self.reference[j] = q;
            self.primed[j] = true;
            *v = q;
        }
        Encoded { bytes: f32_frame_bytes(k), payload }
    }

    /// Delta lanes against the per-coordinate reference (every selected
    /// lane primed). The delivered payload is the updated reference —
    /// absolute values, so receivers scatter without codec state.
    fn encode_delta(&mut self, indices: &[u32], values: Vec<f64>) -> Encoded {
        let k = values.len();
        let mut delta = values;
        for (d, &j) in delta.iter_mut().zip(indices) {
            let j = j as usize;
            *d += self.residual[j] - self.reference[j];
        }
        let (offset, scale) = offset_scale(&delta);
        for (d, &j) in delta.iter_mut().zip(indices) {
            let j = j as usize;
            let qd = quantize(*d, offset, scale);
            let target = self.reference[j] + *d;
            self.reference[j] += qd;
            self.residual[j] = target - self.reference[j];
            *d = self.reference[j];
        }
        Encoded { bytes: f32_frame_bytes(k), payload: delta }
    }
}

/// Per-slice normalization header: midrange offset and half-range
/// scale, so normalized lanes sit in `[−1, 1]`.
fn offset_scale(xs: &[f64]) -> (f64, f64) {
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !(lo <= hi) {
        return (0.0, 0.0); // empty frame
    }
    (0.5 * (lo + hi), 0.5 * (hi - lo))
}

/// Round-trip one lane through the normalized 4-byte representation.
fn quantize(v: f64, offset: f64, scale: f64) -> f64 {
    if scale <= 0.0 {
        // Constant slice: the header alone reconstructs it exactly.
        return offset;
    }
    let norm = ((v - offset) / scale) as f32;
    offset + norm as f64 * scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn max_err(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn parse_roundtrip() {
        for f in [WireFormat::F64, WireFormat::F32, WireFormat::DeltaF32] {
            assert_eq!(WireFormat::parse(f.name()), Some(f));
        }
        assert_eq!(WireFormat::parse("delta-f32"), Some(WireFormat::DeltaF32));
        assert_eq!(WireFormat::parse("bf16"), None);
        assert!(!WireFormat::F64.is_lossy());
        assert!(WireFormat::F32.is_lossy() && WireFormat::DeltaF32.is_lossy());
    }

    #[test]
    fn f64_frames_are_exact_and_full_width() {
        let mut c = StreamCodec::new(WireFormat::F64);
        let v = vec![1.0, -2.5, 1e300, f64::NEG_INFINITY];
        let enc = c.encode(v.clone());
        assert_eq!(enc.payload, v);
        assert_eq!(enc.bytes, 8 * 4);
    }

    #[test]
    fn f32_roundtrip_error_scales_with_the_slice_range() {
        // A slice with a huge common offset (duals/ε regime) but a small
        // range: the scale header keeps the error at ~2⁻²⁴ of the
        // *range*, orders of magnitude below a naive f32 cast of the
        // absolute values.
        let mut rng = Rng::seed_from(31);
        let v: Vec<f64> = (0..257).map(|_| -1.0e4 + rng.uniform_range(-2.0, 2.0)).collect();
        let mut c = StreamCodec::new(WireFormat::F32);
        let enc = c.encode(v.clone());
        assert_eq!(enc.bytes, f32_frame_bytes(257));
        assert!(enc.bytes < f64_frame_bytes(257) * 6 / 10, "≈ half the f64 frame");
        let step = 2.0 * 2.0f64.powi(-24); // scale ≈ range/2 = 2
        assert!(max_err(&enc.payload, &v) <= 4.0 * step, "err {}", max_err(&enc.payload, &v));
        // A naive f32 cast at this magnitude would err by ~1e4·2⁻²⁴ ≈ 6e-4.
        assert!(max_err(&enc.payload, &v) < 1e-5);
    }

    #[test]
    fn constant_and_empty_slices_are_exact() {
        for fmt in [WireFormat::F32, WireFormat::DeltaF32] {
            let mut c = StreamCodec::new(fmt);
            assert!(c.encode(Vec::new()).payload.is_empty());
            let v = vec![3.25; 9];
            assert_eq!(c.encode(v.clone()).payload, v, "{}", fmt.name());
        }
    }

    #[test]
    fn non_finite_frames_fall_back_to_exact() {
        let mut c = StreamCodec::new(WireFormat::DeltaF32);
        let _ = c.encode(vec![1.0, 2.0, 3.0]); // primes the stream
        let v = vec![f64::NEG_INFINITY, 2.0, 3.0];
        let enc = c.encode(v.clone());
        assert_eq!(enc.payload, v);
        assert_eq!(enc.bytes, f64_frame_bytes(3));
        // Stream re-primes cleanly afterwards.
        let v2 = vec![1.0, 2.0, 3.0];
        let enc2 = c.encode(v2.clone());
        assert!(max_err(&enc2.payload, &v2) < 1e-6);
    }

    #[test]
    fn delta_frames_sharpen_as_the_stream_converges() {
        // A contracting iterate sequence: the delta range shrinks every
        // round, so the DeltaF32 error floor shrinks with it while the
        // absolute-F32 floor stays pinned to the slice range.
        let base: Vec<f64> = (0..64).map(|i| (i as f64 * 0.7).sin() * 50.0).collect();
        let mut df = StreamCodec::new(WireFormat::DeltaF32);
        let mut af = StreamCodec::new(WireFormat::F32);
        let mut delta_err = 0.0;
        let mut abs_err = 0.0;
        for round in 0..40 {
            let shrink = 0.5f64.powi(round);
            let v: Vec<f64> =
                base.iter().enumerate().map(|(i, &b)| b + shrink * (i as f64)).collect();
            delta_err = max_err(&df.encode(v.clone()).payload, &v);
            abs_err = max_err(&af.encode(v.clone()).payload, &v);
        }
        assert!(delta_err < abs_err / 100.0, "delta {delta_err} vs abs {abs_err}");
    }

    #[test]
    fn error_feedback_bounds_reconstruction_over_many_rounds() {
        // ≥100 rounds of a drifting slice: the per-round reconstruction
        // error must stay bounded by a few quantization steps of that
        // round's frame — flat over time, not accumulating.
        let mut rng = Rng::seed_from(37);
        for fmt in [WireFormat::F32, WireFormat::DeltaF32] {
            let mut codec = StreamCodec::new(fmt);
            let mut v: Vec<f64> = (0..128).map(|_| rng.uniform_range(-30.0, 30.0)).collect();
            let mut early = 0.0f64;
            let mut late = 0.0f64;
            for round in 0..120 {
                for x in v.iter_mut() {
                    *x += rng.uniform_range(-1e-3, 1e-3);
                }
                let err = max_err(&codec.encode(v.clone()).payload, &v);
                // Frame ranges: F32 ≈ 60 (slice range), DeltaF32 ≈ 2e-3
                // + residual (delta range); both × 2⁻²⁴, with headroom.
                // DeltaF32's round 0 is its absolute keyframe and round
                // 1's delta frame still flushes the keyframe's f32-sized
                // residual — the delta-sized bound holds from round 2
                // (cross-checked against the numpy port of this codec).
                let bound = match fmt {
                    WireFormat::DeltaF32 if round > 1 => 1e-2 * 2.0f64.powi(-24) * 8.0,
                    _ => 60.0 * 2.0f64.powi(-24) * 8.0,
                };
                assert!(err <= bound, "{} round {round}: err {err} > {bound}", fmt.name());
                if round < 10 {
                    early = early.max(err);
                } else if round >= 110 {
                    late = late.max(err);
                }
            }
            // No growth: round-110+ errors comparable to round-0..10.
            assert!(late <= early * 4.0 + 1e-12, "{}: {late} vs {early}", fmt.name());
        }
    }

    #[test]
    fn forced_keyframes_keep_reconstruction_bounded() {
        // `--wire-keyframe-every K`: frames K, 2K, … of a DeltaF32
        // stream are absolute keyframes. A keyframe round (and the
        // delta frame right after it, which flushes the keyframe's
        // f32-sized residual) is bounded by the slice-range f32 step;
        // every other round must hold the much tighter delta-sized
        // bound — and neither bound may grow across cadence cycles.
        let mut rng = Rng::seed_from(41);
        let k = 8usize;
        let mut codec = StreamCodec::with_keyframe_every(WireFormat::DeltaF32, k);
        let mut v: Vec<f64> = (0..96).map(|_| rng.uniform_range(-20.0, 20.0)).collect();
        let key_bound = 40.0 * 2.0f64.powi(-24) * 8.0;
        let delta_bound = 1e-2 * 2.0f64.powi(-24) * 8.0;
        let mut worst = 0.0f64;
        for round in 0..120usize {
            for x in v.iter_mut() {
                *x += rng.uniform_range(-1e-3, 1e-3);
            }
            let err = max_err(&codec.encode(v.clone()).payload, &v);
            let bound = if round % k <= 1 { key_bound } else { delta_bound };
            assert!(err <= bound, "round {round}: err {err} > {bound}");
            worst = worst.max(err);
        }
        assert!(worst <= key_bound, "error grew across forced keyframes: {worst}");
    }

    #[test]
    fn rekey_resets_the_stream_state() {
        // After rekey() the codec must behave exactly like a fresh
        // stream — the fault layer relies on this to keep receiver
        // reconstruction convergent after a lost latest-wins frame.
        let mut used = StreamCodec::new(WireFormat::DeltaF32);
        let _ = used.encode(vec![1.0, 2.0, 3.0]);
        let _ = used.encode(vec![1.1, 2.1, 3.1]);
        used.rekey();
        let mut fresh = StreamCodec::new(WireFormat::DeltaF32);
        let v = vec![5.0, -2.0, 0.5];
        assert_eq!(used.encode(v.clone()).payload, fresh.encode(v).payload);
        // And the stream keeps delta-coding cleanly afterwards.
        let v2 = vec![5.001, -1.999, 0.501];
        let enc = used.encode(v2.clone());
        assert!(max_err(&enc.payload, &v2) < 1e-6);
    }

    #[test]
    fn sparse_index_bytes_pack_clustered_indices_tightly() {
        // Empty frame: just the count varint.
        assert_eq!(sparse_index_bytes(&[]), 1);
        // Dense run 0..10: count byte + 10 single-byte gaps.
        let run: Vec<u32> = (0..10).collect();
        assert_eq!(sparse_index_bytes(&run), 11);
        // A large absolute first index costs varint width, later small
        // gaps stay at one byte each.
        let spread = vec![100_000, 100_001, 100_050];
        assert_eq!(sparse_index_bytes(&spread), 1 + 3 + 1 + 1);
        // Index overhead always beats shipping the dense slice: k=64 of
        // 512 coordinates ≤ ~2 bytes/index + values, far under 512·8.
        let topk: Vec<u32> = (0..64).map(|i| i * 8).collect();
        let sparse = sparse_index_bytes(&topk) + f64_frame_bytes(64);
        assert!(sparse < f64_frame_bytes(512) / 4, "sparse {sparse}");
    }

    #[test]
    fn sparse_f64_frames_are_exact() {
        let mut c = SparseStreamCodec::new(WireFormat::F64);
        let v = vec![1.0, -2.5, 1e300];
        let enc = c.encode(&[3, 7, 11], v.clone(), 16);
        assert_eq!(enc.payload, v);
        assert_eq!(enc.bytes, 8 * 3);
    }

    #[test]
    fn sparse_error_feedback_bounds_reconstruction_over_many_rounds() {
        // 120 rounds of a drifting 128-wide slice, each round updating a
        // different pseudo-random top-k subset: the per-round
        // reconstruction error at the selected coordinates must stay
        // bounded by a few quantization steps — flat over time, per
        // coordinate, not accumulating (satellite-3 roundtrip pin).
        let mut rng = Rng::seed_from(53);
        for fmt in [WireFormat::F32, WireFormat::DeltaF32] {
            let mut codec = SparseStreamCodec::new(fmt);
            let mut v: Vec<f64> = (0..128).map(|_| rng.uniform_range(-30.0, 30.0)).collect();
            let mut early = 0.0f64;
            let mut late = 0.0f64;
            for round in 0..120 {
                for x in v.iter_mut() {
                    *x += rng.uniform_range(-1e-3, 1e-3);
                }
                // A different 32-coordinate subset every round.
                let mut idx: Vec<u32> = (0..128u32)
                    .filter(|_| rng.uniform() < 0.25)
                    .collect();
                if idx.is_empty() {
                    idx.push((round % 128) as u32);
                }
                let vals: Vec<f64> = idx.iter().map(|&j| v[j as usize]).collect();
                let enc = codec.encode(&idx, vals.clone(), 128);
                assert_eq!(enc.payload.len(), idx.len());
                let err = max_err(&enc.payload, &vals);
                // Selected values span ≈ the slice range (60); unprimed
                // lanes keep forcing absolute frames early on, so both
                // formats hold the slice-range f32 bound. Once every
                // lane has primed, DeltaF32 frames tighten further, but
                // re-selections after long gaps carry real deltas — the
                // slice-range bound (with headroom) is the honest pin.
                let bound = 60.0 * 2.0f64.powi(-24) * 16.0;
                assert!(err <= bound, "{} round {round}: err {err} > {bound}", fmt.name());
                if round < 10 {
                    early = early.max(err);
                } else if round >= 110 {
                    late = late.max(err);
                }
            }
            assert!(late <= early * 8.0 + 1e-12, "{}: {late} vs {early}", fmt.name());
        }
    }

    #[test]
    fn sparse_delta_frames_tighten_once_lanes_are_primed() {
        // A fixed selected subset with contracting updates: after the
        // priming frame, DeltaF32 lanes difference against the
        // per-coordinate reference and the error shrinks with the delta
        // range, far below the absolute-F32 floor.
        let idx: Vec<u32> = (0..32).map(|i| i * 3).collect();
        let base: Vec<f64> = idx.iter().map(|&j| (j as f64 * 0.7).sin() * 50.0).collect();
        let mut df = SparseStreamCodec::new(WireFormat::DeltaF32);
        let mut af = SparseStreamCodec::new(WireFormat::F32);
        let mut delta_err = 0.0;
        let mut abs_err = 0.0;
        for round in 0..30 {
            let shrink = 0.5f64.powi(round);
            let vals: Vec<f64> =
                base.iter().enumerate().map(|(i, &b)| b + shrink * (i as f64)).collect();
            delta_err = max_err(&df.encode(&idx, vals.clone(), 128).payload, &vals);
            abs_err = max_err(&af.encode(&idx, vals.clone(), 128).payload, &vals);
        }
        assert!(delta_err < abs_err / 100.0, "delta {delta_err} vs abs {abs_err}");
    }

    #[test]
    fn sparse_rekey_forces_absolute_reprime() {
        // After rekey() (latest-wins loss) the next frame touching any
        // coordinate must be near-exact — an absolute frame, not a
        // delta against state the receiver never saw.
        let idx = vec![1u32, 4, 9];
        let mut c = SparseStreamCodec::new(WireFormat::DeltaF32);
        let _ = c.encode(&idx, vec![10.0, 20.0, 30.0], 16);
        let _ = c.encode(&idx, vec![10.1, 20.1, 30.1], 16);
        c.rekey();
        let v = vec![-5.0, 7.0, 100.0];
        let enc = c.encode(&idx, v.clone(), 16);
        let step = 52.5 * 2.0f64.powi(-24) * 8.0; // range/2 ≈ 52.5
        assert!(max_err(&enc.payload, &v) <= step, "err {}", max_err(&enc.payload, &v));
        // And delta-codes cleanly afterwards.
        let v2 = vec![-4.999, 7.001, 100.001];
        let enc2 = c.encode(&idx, v2.clone(), 16);
        assert!(max_err(&enc2.payload, &v2) < 1e-5);
    }

    #[test]
    fn sparse_unprimed_lane_forces_absolute_frame() {
        // Coordinates 0..4 primed; a later frame adding coordinate 12
        // must go absolute (12 has no reference) — and prime it.
        let mut c = SparseStreamCodec::new(WireFormat::DeltaF32);
        let idx1 = vec![0u32, 1, 2, 3];
        let _ = c.encode(&idx1, vec![1.0, 2.0, 3.0, 4.0], 16);
        let idx2 = vec![0u32, 12];
        let v2 = vec![1.5, 80.0];
        let enc2 = c.encode(&idx2, v2.clone(), 16);
        let step = 39.25 * 2.0f64.powi(-24) * 8.0;
        assert!(max_err(&enc2.payload, &v2) <= step);
        // Now 12 is primed: a pure-delta frame follows.
        let v3 = vec![1.501, 80.001];
        let enc3 = c.encode(&idx2, v3.clone(), 16);
        assert!(max_err(&enc3.payload, &v3) < 1e-5);
    }

    #[test]
    fn length_change_reprimes_the_delta_stream() {
        let mut c = StreamCodec::new(WireFormat::DeltaF32);
        let _ = c.encode(vec![1.0; 8]);
        let v = vec![2.0, 4.0, 8.0]; // different length: keyframe
        let enc = c.encode(v.clone());
        assert!(max_err(&enc.payload, &v) < 1e-5);
        let v2 = vec![2.1, 4.1, 8.1];
        let enc2 = c.encode(v2.clone());
        assert!(max_err(&enc2.payload, &v2) < 1e-6);
    }
}
