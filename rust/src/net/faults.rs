//! Deterministic fault injection for the simulated fabric.
//!
//! The paper's protocols assume a reliable, lossless exchange; real
//! federated deployments (and the decentralized topologies on the
//! roadmap) do not get one. A [`FaultPlan`] describes per-link loss
//! behaviour — drop / duplicate / reorder probabilities and delay
//! spikes — plus node-level injections (crash at an iteration,
//! straggler slowdown). The fabric consults the plan on every send.
//!
//! **Determinism.** Every frame's fault roll is drawn from an RNG
//! seeded purely by `(plan seed, src, dst, link sequence number)` —
//! see [`FaultPlan::roll`]. The link sequence counter for `(src, dst)`
//! is only ever advanced by node `src`'s own sends, so the counter
//! value a frame observes is a function of program order on that one
//! thread, never of cross-thread interleaving: a given seed replays
//! the exact same drop/dup/reorder/spike schedule at any thread count.
//! The `pool_parity`-style property test in `rust/tests/faults.rs`
//! pins this.
//!
//! **Two delivery classes.** The fabric heals faults differently per
//! stream class (see [`crate::net::Endpoint`]):
//!
//! * *Reliable* (`send`/`send_coded`) — lock-step sync traffic, votes,
//!   final gathers. A dropped attempt is retransmitted after a
//!   deadline-based timeout with exponential backoff; the timeout is
//!   the per-link adaptive [`LinkRtt`] estimate once the link has seen
//!   clean traffic, with the deterministic [`rto_secs`] transfer
//!   estimate as cold-start prior (backoff via [`backoff_secs`]).
//!   Because the schedule is decided at send time,
//!   the fabric "fast-forwards" the ARQ: it prices every failed
//!   attempt (frame bytes + a nack frame) into the traffic counters
//!   and stretches the delivery deadline by the accumulated backoff,
//!   then enqueues the surviving copy. The delivered payload is
//!   byte-identical to the lossless wire — only *when* it arrives (and
//!   what it cost) changes, which is why sync iterates stay bit-exact
//!   under loss.
//! * *Latest-wins* (`send_latest`/`send_coded_latest`) — async duals,
//!   fleet probes/commands, async star chunks. Retransmitting a stale
//!   frame is pointless when the next send supersedes it, so a dropped
//!   or reordered frame is simply lost (priced, counted, never
//!   delivered) and a DeltaF32 stream re-keys
//!   ([`crate::net::wire::StreamCodec::rekey`]) so the receiver's
//!   reconstruction can never diverge from the sender's reference.

use super::LatencyModel;
use crate::rng::{splitmix64, Rng};
use std::collections::HashMap;

/// Per-link fault probabilities, applied independently per frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFault {
    /// Probability one transmission attempt is lost.
    pub drop_prob: f64,
    /// Probability the frame is delivered twice.
    pub dup_prob: f64,
    /// Probability the frame arrives out of order (reliable streams
    /// absorb this as head-of-line delay; latest-wins streams lose the
    /// frame — it would arrive already superseded).
    pub reorder_prob: f64,
    /// `(probability, multiplier)` of a fault-layer delay spike on top
    /// of the latency model's own jitter/spikes.
    pub delay_spike: (f64, f64),
}

impl LinkFault {
    /// A clean link.
    pub fn none() -> Self {
        Self { drop_prob: 0.0, dup_prob: 0.0, reorder_prob: 0.0, delay_spike: (0.0, 1.0) }
    }

    /// Whether any fault can fire on this link.
    pub fn is_active(&self) -> bool {
        self.drop_prob > 0.0
            || self.dup_prob > 0.0
            || self.reorder_prob > 0.0
            || self.delay_spike.0 > 0.0
    }
}

impl Default for LinkFault {
    fn default() -> Self {
        Self::none()
    }
}

/// Node-level injections.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeFault {
    /// Crash (silent exit) when the node's local iteration counter
    /// reaches this value — checked at the top of each coordinator
    /// iteration, so peers see a clean cut at a round boundary.
    pub crash_at_iter: Option<u64>,
    /// Multiplier on every delivery delay of frames this node *sends*
    /// (a slow node is late on the wire); 1.0 = none.
    pub straggler_mult: f64,
}

impl Default for NodeFault {
    fn default() -> Self {
        Self { crash_at_iter: None, straggler_mult: 1.0 }
    }
}

/// Cap on consecutive dropped attempts of one frame, so a pathological
/// drop probability cannot stall a reliable stream unboundedly.
pub const MAX_DROPS_PER_FRAME: u32 = 16;

/// The faults rolled for one frame, in fixed draw order (drop
/// attempts, dup, reorder, spike) so a `(seed, src, dst, seq)` tuple
/// always yields the same schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrameFaults {
    /// Transmission attempts lost before the surviving one. Reliable
    /// streams retransmit (backoff-priced); latest-wins streams lose
    /// the frame whenever this is nonzero.
    pub drops: u32,
    pub duplicated: bool,
    pub reordered: bool,
    /// Delay multiplier from the spike roll (1.0 = no spike).
    pub spike_mult: f64,
}

impl FrameFaults {
    /// A clean roll.
    pub fn none() -> Self {
        Self { drops: 0, duplicated: false, reordered: false, spike_mult: 1.0 }
    }
}

impl Default for FrameFaults {
    fn default() -> Self {
        Self::none()
    }
}

/// Fault-injection schedule for one run: a default link fault, per-link
/// overrides keyed `(src, dst)`, and per-node injections.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed of the fault schedule — independent of the solver seed so
    /// the same faults can replay across different problems.
    pub seed: u64,
    /// Fault applied to every link without an override.
    pub default_link: LinkFault,
    /// Per-link overrides.
    pub links: HashMap<(usize, usize), LinkFault>,
    /// Per-node injections.
    pub nodes: HashMap<usize, NodeFault>,
}

impl FaultPlan {
    /// The empty plan: every link clean, no node injections.
    pub fn none() -> Self {
        Self::default()
    }

    /// Whether any fault can ever fire — the fabric's fast-path guard:
    /// an inactive plan leaves the send/receive paths byte-for-byte on
    /// the lossless code.
    pub fn is_active(&self) -> bool {
        self.default_link.is_active()
            || self.links.values().any(|l| l.is_active())
            || self
                .nodes
                .values()
                .any(|n| n.crash_at_iter.is_some() || n.straggler_mult != 1.0)
    }

    /// Effective fault of link `(src, dst)`.
    pub fn link(&self, src: usize, dst: usize) -> LinkFault {
        self.links.get(&(src, dst)).copied().unwrap_or(self.default_link)
    }

    /// Crash iteration of node `id`, if injected.
    pub fn crash_at(&self, id: usize) -> Option<u64> {
        self.nodes.get(&id).and_then(|n| n.crash_at_iter)
    }

    /// Send-delay multiplier of node `id` (1.0 when clean).
    pub fn straggler_mult(&self, id: usize) -> f64 {
        self.nodes.get(&id).map(|n| n.straggler_mult).unwrap_or(1.0)
    }

    /// Roll the faults of frame `seq` on link `(src, dst)`. Pure in
    /// `(self.seed, src, dst, seq)` — same tuple, same roll, regardless
    /// of when or on which thread the send happens.
    pub fn roll(&self, src: usize, dst: usize, seq: u64) -> FrameFaults {
        let lf = self.link(src, dst);
        if !lf.is_active() {
            return FrameFaults::none();
        }
        let mut state = self
            .seed
            .wrapping_add((src as u64).wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add((dst as u64).wrapping_mul(0xC2B2AE3D27D4EB4F))
            .wrapping_add(seq.wrapping_mul(0x165667B19E3779F9));
        let mut rng = Rng::seed_from(splitmix64(&mut state));
        let mut drops = 0u32;
        while lf.drop_prob > 0.0
            && drops < MAX_DROPS_PER_FRAME
            && rng.uniform() < lf.drop_prob
        {
            drops += 1;
        }
        let duplicated = lf.dup_prob > 0.0 && rng.uniform() < lf.dup_prob;
        let reordered = lf.reorder_prob > 0.0 && rng.uniform() < lf.reorder_prob;
        let spike_mult = if lf.delay_spike.0 > 0.0 && rng.uniform() < lf.delay_spike.0 {
            lf.delay_spike.1.max(1.0)
        } else {
            1.0
        };
        FrameFaults { drops, duplicated, reordered, spike_mult }
    }
}

/// What a sync coordinator does when a peer is declared dead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeLoss {
    /// Abort the solve with a structured partial outcome
    /// (`StopReason::PeerLoss`, `degraded = true`).
    Abort,
    /// Freeze the dead node's slice at its last received value and keep
    /// iterating over the survivors; the outcome is flagged degraded.
    Exclude,
}

impl NodeLoss {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "abort" => Some(NodeLoss::Abort),
            "exclude" => Some(NodeLoss::Exclude),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NodeLoss::Abort => "abort",
            NodeLoss::Exclude => "exclude",
        }
    }
}

/// Peer-death detection parameters (`--recv-timeout` / `--strikes` /
/// `--on-node-loss`): a blocking receive that times out `strikes`
/// times in a row on the same peer declares it dead.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Recovery {
    /// Per-attempt receive timeout in seconds.
    pub recv_timeout_secs: f64,
    /// Consecutive timeouts before a peer is declared dead.
    pub strikes: u32,
    pub on_node_loss: NodeLoss,
}

impl Default for Recovery {
    fn default() -> Self {
        Self { recv_timeout_secs: 0.5, strikes: 4, on_node_loss: NodeLoss::Abort }
    }
}

impl Recovery {
    /// Wall-clock budget before a silent peer is declared dead.
    pub fn death_secs(&self) -> f64 {
        self.recv_timeout_secs * self.strikes as f64
    }
}

/// Deterministic retransmit-timeout *prior* for a `bytes`-sized frame
/// on `latency`: twice the one-way transfer estimate, floored so
/// zero-latency test fabrics still pay a visible per-loss penalty.
///
/// This is only the cold-start estimate: once a link has seen a clean
/// delivery, the fabric's per-link [`LinkRtt`] EWMA supersedes it (see
/// [`LinkRtt::rto_secs`]) — jittery or spiky links earn a wider timer
/// than the model's deterministic terms predict, quiet ones a tighter
/// one, exactly like a TCP sender's adaptive RTO.
pub fn rto_secs(latency: &LatencyModel, bytes: usize) -> f64 {
    (2.0 * (latency.base_secs + latency.beta_secs(bytes as u64))).max(100e-6)
}

/// Per-link smoothed delivery-delay estimator driving the adaptive
/// retransmit timer — the RFC 6298 EWMA pair (SRTT / RTTVAR).
///
/// The fabric keeps one per directed link and folds in the delay of
/// every *clean* delivery: frames that were dropped (retransmitted) or
/// reorder-held never sample the timer (Karn's rule — their delay
/// includes the very backoff the timer decides, so sampling them would
/// feed the estimator its own output). Until the first sample lands the
/// link is unprimed and [`LinkRtt::rto_secs`] falls back to the
/// deterministic [`rto_secs`] prior.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkRtt {
    /// Smoothed delivery delay (EWMA, gain 1/8).
    pub srtt: f64,
    /// Smoothed delay deviation (EWMA, gain 1/4).
    pub rttvar: f64,
    /// Whether any sample has landed (unprimed links use the prior).
    pub primed: bool,
}

impl LinkRtt {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one clean delivery-delay sample (seconds). Non-finite or
    /// negative samples are ignored.
    pub fn observe(&mut self, sample: f64) {
        if !sample.is_finite() || sample < 0.0 {
            return;
        }
        if !self.primed {
            // RFC 6298 §2.2 first-sample initialization.
            self.srtt = sample;
            self.rttvar = sample / 2.0;
            self.primed = true;
        } else {
            // §2.3: RTTVAR before SRTT, so the deviation is measured
            // against the pre-update mean.
            self.rttvar = 0.75 * self.rttvar + 0.25 * (self.srtt - sample).abs();
            self.srtt = 0.875 * self.srtt + 0.125 * sample;
        }
    }

    /// The adaptive retransmit timeout: `SRTT + 4·RTTVAR` once primed
    /// (floored like the deterministic prior), else `prior` itself.
    pub fn rto_secs(&self, prior: f64) -> f64 {
        if self.primed {
            (self.srtt + 4.0 * self.rttvar).max(100e-6)
        } else {
            prior
        }
    }
}

/// Total backoff delay of `attempts` consecutive failed transmissions
/// under exponential backoff (`rto`, `2·rto`, `4·rto`, …):
/// `rto · (2^attempts − 1)`.
pub fn backoff_secs(rto: f64, attempts: u32) -> f64 {
    if attempts == 0 {
        return 0.0;
    }
    rto * ((1u64 << attempts.min(32)) - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lossy() -> FaultPlan {
        FaultPlan {
            seed: 9,
            default_link: LinkFault {
                drop_prob: 0.2,
                dup_prob: 0.1,
                reorder_prob: 0.1,
                delay_spike: (0.05, 6.0),
            },
            ..FaultPlan::none()
        }
    }

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        assert_eq!(p.roll(0, 1, 7), FrameFaults::none());
        assert_eq!(p.crash_at(3), None);
        assert_eq!(p.straggler_mult(3), 1.0);
    }

    #[test]
    fn same_seed_same_schedule() {
        let (a, b) = (lossy(), lossy());
        for src in 0..4 {
            for dst in 0..4 {
                for seq in 0..200 {
                    assert_eq!(a.roll(src, dst, seq), b.roll(src, dst, seq));
                }
            }
        }
    }

    #[test]
    fn schedule_varies_by_link_seq_and_seed() {
        let a = lossy();
        let b = FaultPlan { seed: 10, ..lossy() };
        let differs = |f: &dyn Fn(u64) -> FrameFaults, g: &dyn Fn(u64) -> FrameFaults| {
            (0..300).any(|s| f(s) != g(s))
        };
        assert!(differs(&|s| a.roll(0, 1, s), &|s| a.roll(1, 0, s)));
        assert!(differs(&|s| a.roll(0, 1, s), &|s| a.roll(0, 2, s)));
        assert!(differs(&|s| a.roll(0, 1, s), &|s| b.roll(0, 1, s)));
        // And the schedule actually exercises every fault type.
        let rolls: Vec<FrameFaults> = (0..500).map(|s| a.roll(0, 1, s)).collect();
        assert!(rolls.iter().any(|f| f.drops > 0));
        assert!(rolls.iter().any(|f| f.duplicated));
        assert!(rolls.iter().any(|f| f.reordered));
        assert!(rolls.iter().any(|f| f.spike_mult > 1.0));
    }

    #[test]
    fn drop_rate_tracks_probability() {
        let p = FaultPlan {
            seed: 3,
            default_link: LinkFault { drop_prob: 0.3, ..LinkFault::none() },
            ..FaultPlan::none()
        };
        let n = 20_000u64;
        let lost = (0..n).filter(|&s| p.roll(0, 1, s).drops > 0).count() as f64;
        assert!((lost / n as f64 - 0.3).abs() < 0.02, "rate {}", lost / n as f64);
    }

    #[test]
    fn per_link_overrides_and_node_injections() {
        let mut p = FaultPlan::none();
        assert!(!p.is_active());
        p.links.insert((2, 0), LinkFault { drop_prob: 1.0, ..LinkFault::none() });
        p.nodes
            .insert(1, NodeFault { crash_at_iter: Some(40), straggler_mult: 3.0 });
        assert!(p.is_active());
        assert_eq!(p.roll(0, 2, 0), FrameFaults::none());
        assert_eq!(p.roll(2, 0, 0).drops, MAX_DROPS_PER_FRAME);
        assert_eq!(p.crash_at(1), Some(40));
        assert_eq!(p.crash_at(2), None);
        assert_eq!(p.straggler_mult(1), 3.0);
        assert_eq!(p.straggler_mult(0), 1.0);
    }

    #[test]
    fn backoff_doubles_and_rto_floors() {
        let zero = LatencyModel::zero();
        let rto = rto_secs(&zero, 1024);
        assert!(rto >= 100e-6, "zero-latency floor");
        assert_eq!(backoff_secs(rto, 0), 0.0);
        assert!((backoff_secs(rto, 1) - rto).abs() < 1e-12);
        assert!((backoff_secs(rto, 3) - 7.0 * rto).abs() < 1e-12);
        let lan = LatencyModel::lan();
        assert!(rto_secs(&lan, 1 << 20) > rto_secs(&lan, 64));
    }

    #[test]
    fn link_rtt_cold_start_uses_prior() {
        let r = LinkRtt::new();
        assert!(!r.primed);
        assert_eq!(r.rto_secs(0.5), 0.5);
        // Garbage samples leave the estimator unprimed.
        let mut g = LinkRtt::new();
        g.observe(f64::NAN);
        g.observe(-1.0);
        assert!(!g.primed);
        assert_eq!(g.rto_secs(0.25), 0.25);
    }

    #[test]
    fn link_rtt_ewma_tracks_samples() {
        let mut r = LinkRtt::new();
        r.observe(0.010);
        assert!(r.primed);
        assert!((r.srtt - 0.010).abs() < 1e-12);
        assert!((r.rttvar - 0.005).abs() < 1e-12);
        assert!((r.rto_secs(9.0) - 0.030).abs() < 1e-12, "srtt + 4·rttvar");
        // Steady samples collapse the variance term toward the mean.
        for _ in 0..200 {
            r.observe(0.010);
        }
        assert!((r.srtt - 0.010).abs() < 1e-9);
        assert!(r.rto_secs(9.0) < 0.011);
        // A delay burst inflates the timer; steady traffic relaxes it.
        r.observe(0.100);
        let inflated = r.rto_secs(9.0);
        assert!(inflated > 0.05, "burst must widen the timer: {inflated}");
        for _ in 0..300 {
            r.observe(0.010);
        }
        assert!(r.rto_secs(9.0) < inflated / 4.0);
        // The primed timer never collapses below the floor.
        let mut tiny = LinkRtt::new();
        tiny.observe(0.0);
        assert!(tiny.primed);
        assert_eq!(tiny.rto_secs(9.0), 100e-6);
    }

    #[test]
    fn node_loss_parse_roundtrip() {
        for m in [NodeLoss::Abort, NodeLoss::Exclude] {
            assert_eq!(NodeLoss::parse(m.name()), Some(m));
        }
        assert_eq!(NodeLoss::parse("panic"), None);
        assert_eq!(Recovery::default().on_node_loss, NodeLoss::Abort);
        let r = Recovery { recv_timeout_secs: 0.25, strikes: 4, ..Recovery::default() };
        assert!((r.death_secs() - 1.0).abs() < 1e-12);
    }
}
