//! Mailbox fabric: per-node inboxes with delivery deadlines.

use super::faults::{self, FaultPlan, FrameFaults, LinkRtt};
use super::wire::{self, StreamCodec, WireFormat};
use super::LatencyModel;
use crate::rng::{child_seed, Rng};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Message kinds — the Sinkhorn protocol exchanges the two scaling
/// vectors, small control payloads, (fleet-absorption runs) the
/// reference-dual synchronization traffic, and (greedy exchange) the
/// sparse coordinate-update frames.
///
/// The discriminant IS the counter index (`index()` = `self as usize`),
/// and [`TagKind::ALL`]/[`TagKind::COUNT`] are the single derived kind
/// list every per-kind counter array and traffic snapshot iterates — a
/// new kind added here is automatically counted everywhere (pinned by
/// `every_kind_has_a_counter`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TagKind {
    /// u-slice broadcast.
    U,
    /// v-slice broadcast.
    V,
    /// Control (barriers, convergence votes, stop decisions) — exact
    /// frames — plus, in the star topology, the server's q/r product
    /// chunks, which are bulk data and DO ride the wire codec: a star
    /// run's `Ctl` byte bucket is therefore dominated by coded chunk
    /// traffic, not by the (negligible, always-exact) votes.
    Ctl,
    /// Fleet-synchronized absorption: slice-local drift probes to the
    /// coordinator and the reference-dual `ḡ` broadcast back. Priced by
    /// the same α–β latency model as every other message (`α` base +
    /// `β`·bytes), so the protocol's extra per-iteration term shows up
    /// honestly in the comm-time buckets the paper reports.
    Gref,
    /// Sparse u-coordinate updates (`--exchange greedy`): varint-packed
    /// indices + coded values of the top-k violating rows only.
    SparseU,
    /// Sparse v-coordinate updates (greedy exchange).
    SparseV,
}

impl TagKind {
    /// Number of declared kinds — sizes every per-kind counter array.
    pub const COUNT: usize = 6;

    /// Every kind, in counter order.
    pub const ALL: [TagKind; Self::COUNT] = [
        TagKind::U,
        TagKind::V,
        TagKind::Ctl,
        TagKind::Gref,
        TagKind::SparseU,
        TagKind::SparseV,
    ];

    /// Stable counter index (the declaration-order discriminant).
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            TagKind::U => "U",
            TagKind::V => "V",
            TagKind::Ctl => "Ctl",
            TagKind::Gref => "Gref",
            TagKind::SparseU => "SpU",
            TagKind::SparseV => "SpV",
        }
    }
}

/// Fixed per-message envelope cost (routing metadata, tag, iteration
/// stamp) on top of the encoded frame.
const MSG_HEADER_BYTES: usize = 64;

/// A gap-detection nack is a header-only control frame; each failed
/// attempt of a reliable frame is priced as frame-out + nack-back.
const NACK_FRAME_BYTES: usize = MSG_HEADER_BYTES;

/// `FEDSINK_STALL_SECS` — stall watchdog of the unbounded blocking
/// receives: after this many seconds without a matching deliverable
/// frame the node panics with a dump of its pending inbox instead of
/// hanging silently. Unset/non-positive = off (the default). Read per
/// receive so tests can toggle it.
fn stall_limit() -> Option<Duration> {
    parse_stall(std::env::var("FEDSINK_STALL_SECS").ok().as_deref())
}

fn parse_stall(v: Option<&str>) -> Option<Duration> {
    v.and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|&s| s > 0.0)
        .map(Duration::from_secs_f64)
}

/// One in-flight message.
#[derive(Clone, Debug)]
pub struct Message {
    pub src: usize,
    pub kind: TagKind,
    /// Protocol round or collective id — keeps rounds from crossing.
    pub tag: u64,
    /// Decoded frame content: for coded streams this is exactly the
    /// receiver-side reconstruction the wire codec produces (frames of a
    /// stream decode in send order, so the sender-tracked reconstruction
    /// *is* the decode — see [`crate::net::wire`]).
    pub payload: Vec<f64>,
    /// Sparse-frame coordinate carriage: `indices[i]` is the position
    /// (within the sender's slice) that `payload[i]` updates. Empty for
    /// dense frames — the receiver branches on `indices.is_empty()`.
    pub indices: Vec<u32>,
    /// Sender's local iteration when it sent (staleness accounting).
    pub sent_iter: u64,
    /// Per-link send sequence number (0 when the fault layer is
    /// inactive). A duplicated frame's copies share it — the receive
    /// paths sweep same-`(src, kind, tag, seq)` siblings on take.
    pub seq: u64,
    /// Receiver-side decode cost of this frame (seconds), stamped at
    /// enqueue from the latency model's per-byte decode term — the
    /// receiving endpoint accumulates it on receive and the coordinator
    /// prices it into its **comp** bucket.
    decode_secs: f64,
    /// Wall-clock deadline before which the receiver may not observe it.
    deliver_at: Instant,
}

#[derive(Default)]
struct Inbox {
    queue: Mutex<Vec<Message>>,
    signal: Condvar,
    /// Monotone arrival counter, bumped under the queue lock on every
    /// enqueue — the "did anything land since I looked" signal behind
    /// [`Endpoint::wait_traffic`].
    seq: AtomicU64,
}

/// Per-[`TagKind`] traffic counters plus totals, read off the fabric's
/// atomics after a run — the comm-bucket breakdown `perf-grid` and
/// `timing` surface next to the wall-time buckets.
#[derive(Clone, Debug, Default)]
pub struct NetTraffic {
    pub total_bytes: u64,
    pub total_msgs: u64,
    /// `(kind name, bytes, messages)` in [`TagKind::ALL`] order.
    pub by_kind: Vec<(&'static str, u64, u64)>,
    /// Fault-layer counters (all zero when the [`FaultPlan`] is
    /// inactive): lost transmission attempts, delivered duplicate
    /// copies, reordered frames, backoff-priced retransmissions on the
    /// reliable streams, and fault-layer delay spikes.
    pub drops: u64,
    pub dups: u64,
    pub reorders: u64,
    pub retransmits: u64,
    pub spikes: u64,
}

impl NetTraffic {
    /// Bytes sent on one kind (0 for an unknown name).
    pub fn bytes_of(&self, kind: TagKind) -> u64 {
        self.by_kind
            .iter()
            .find(|(name, _, _)| *name == kind.name())
            .map(|&(_, b, _)| b)
            .unwrap_or(0)
    }
}

/// The shared fabric: `nodes` inboxes + the latency model + wire codec.
pub struct SimNet {
    inboxes: Vec<Inbox>,
    latency: LatencyModel,
    seed: u64,
    wire: WireFormat,
    /// Forced-keyframe cadence for DeltaF32 streams
    /// (`--wire-keyframe-every`; 0 = off). Handed to every
    /// [`StreamCodec`] the endpoints create.
    keyframe_every: usize,
    /// Per-kind traffic counters, one slot per [`TagKind::ALL`] entry.
    /// Atomics keep the accounting off the send hot path's locks (the
    /// queue mutex is per-inbox; these are global and would otherwise
    /// serialize every sender).
    kind_bytes: [AtomicU64; TagKind::COUNT],
    kind_msgs: [AtomicU64; TagKind::COUNT],
    /// Fault-injection schedule (`FaultPlan::none()` = lossless fabric,
    /// the byte-for-byte pre-fault send/receive paths).
    faults: FaultPlan,
    /// Per-link send sequence counters, indexed `src · nodes + dst`.
    /// Each counter is only ever advanced by node `src`'s own sends, so
    /// the sequence a frame draws its fault roll from is program order
    /// on one thread — deterministic at any thread interleaving.
    link_seq: Vec<AtomicU64>,
    /// Per-link adaptive retransmit-timer state, indexed
    /// `src · nodes + dst` like `link_seq`. Only the sender of a link
    /// ever touches its entry (samples are folded at enqueue, on the
    /// sending thread), so the lock is uncontended and — like the fault
    /// rolls — the estimator's trajectory is pure program order on one
    /// thread, deterministic at any thread count.
    link_rtt: Vec<Mutex<LinkRtt>>,
    /// Fault counters: drops, dups, reorders, retransmits, spikes.
    n_drops: AtomicU64,
    n_dups: AtomicU64,
    n_reorders: AtomicU64,
    n_retransmits: AtomicU64,
    n_spikes: AtomicU64,
}

impl SimNet {
    pub fn new(nodes: usize, latency: LatencyModel, seed: u64) -> Self {
        Self::with_wire(nodes, latency, seed, WireFormat::F64)
    }

    /// Fabric whose coded streams ride `wire` (`--wire-format`); exact
    /// control traffic is unaffected.
    pub fn with_wire(nodes: usize, latency: LatencyModel, seed: u64, wire: WireFormat) -> Self {
        Self {
            inboxes: (0..nodes).map(|_| Inbox::default()).collect(),
            latency,
            seed,
            wire,
            keyframe_every: 0,
            kind_bytes: Default::default(),
            kind_msgs: Default::default(),
            faults: FaultPlan::none(),
            link_seq: (0..nodes * nodes).map(|_| AtomicU64::new(0)).collect(),
            link_rtt: (0..nodes * nodes).map(|_| Mutex::new(LinkRtt::new())).collect(),
            n_drops: AtomicU64::new(0),
            n_dups: AtomicU64::new(0),
            n_reorders: AtomicU64::new(0),
            n_retransmits: AtomicU64::new(0),
            n_spikes: AtomicU64::new(0),
        }
    }

    /// Builder: force a DeltaF32 keyframe every `k` frames on every
    /// coded stream (0 = off, the default).
    pub fn with_keyframe_every(mut self, k: usize) -> Self {
        self.keyframe_every = k;
        self
    }

    /// Builder: inject faults per `plan`. An inactive plan leaves every
    /// send/receive path on the lossless code.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// The fault schedule this fabric runs under.
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    pub fn nodes(&self) -> usize {
        self.inboxes.len()
    }

    pub fn wire(&self) -> WireFormat {
        self.wire
    }

    /// Total payload bytes pushed through the fabric — priced on the
    /// *encoded* frames for coded streams.
    pub fn bytes_sent(&self) -> u64 {
        self.kind_bytes.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Bytes sent on one message kind.
    pub fn kind_bytes(&self, kind: TagKind) -> u64 {
        self.kind_bytes[kind.index()].load(Ordering::Relaxed)
    }

    /// Messages sent on one message kind.
    pub fn kind_msgs(&self, kind: TagKind) -> u64 {
        self.kind_msgs[kind.index()].load(Ordering::Relaxed)
    }

    /// Snapshot of the per-kind counters.
    pub fn traffic(&self) -> NetTraffic {
        let by_kind: Vec<(&'static str, u64, u64)> = TagKind::ALL
            .iter()
            .map(|&k| (k.name(), self.kind_bytes(k), self.kind_msgs(k)))
            .collect();
        NetTraffic {
            total_bytes: by_kind.iter().map(|&(_, b, _)| b).sum(),
            total_msgs: by_kind.iter().map(|&(_, _, m)| m).sum(),
            by_kind,
            drops: self.n_drops.load(Ordering::Relaxed),
            dups: self.n_dups.load(Ordering::Relaxed),
            reorders: self.n_reorders.load(Ordering::Relaxed),
            retransmits: self.n_retransmits.load(Ordering::Relaxed),
            spikes: self.n_spikes.load(Ordering::Relaxed),
        }
    }

    /// Create the handle node `id` uses to talk to the fabric. Each
    /// endpoint carries its own jitter RNG stream so runs are
    /// deterministic given (seed, thread schedule).
    pub fn endpoint(self: &std::sync::Arc<Self>, id: usize) -> Endpoint {
        assert!(id < self.nodes());
        Endpoint {
            net: self.clone(),
            id,
            rng: Mutex::new(Rng::seed_from(child_seed(self.seed, id as u64))),
            codecs: Mutex::new(HashMap::new()),
            sparse_codecs: Mutex::new(HashMap::new()),
            release: Mutex::new(HashMap::new()),
            decode_nanos: AtomicU64::new(0),
        }
    }

    /// Reserve the next send sequence number of link `(src, dst)`.
    fn next_link_seq(&self, src: usize, dst: usize) -> u64 {
        self.link_seq[src * self.nodes() + dst].fetch_add(1, Ordering::Relaxed)
    }

    /// Adaptive retransmit timeout of link `(src, dst)`: the EWMA
    /// estimate once the link is primed, else `prior` (the
    /// deterministic [`faults::rto_secs`] transfer estimate).
    fn link_rto(&self, src: usize, dst: usize, prior: f64) -> f64 {
        self.link_rtt[src * self.nodes() + dst].lock().unwrap().rto_secs(prior)
    }

    /// Fold one clean delivery-delay sample into link `(src, dst)`'s
    /// retransmit-timer state.
    fn observe_link_delay(&self, src: usize, dst: usize, sample: f64) {
        self.link_rtt[src * self.nodes() + dst].lock().unwrap().observe(sample);
    }

    /// Snapshot of link `(src, dst)`'s adaptive retransmit-timer state
    /// — tests and diagnostics.
    pub fn link_rtt(&self, src: usize, dst: usize) -> LinkRtt {
        *self.link_rtt[src * self.nodes() + dst].lock().unwrap()
    }
}

/// A node's handle to the fabric.
pub struct Endpoint {
    net: std::sync::Arc<SimNet>,
    id: usize,
    rng: Mutex<Rng>,
    /// Sender-held wire-codec state per `(dst, kind, stream)` coded
    /// stream (delta reference + error-feedback residual). Only
    /// [`Endpoint::send_coded`] consults it; exact control sends bypass
    /// the map entirely.
    codecs: Mutex<HashMap<(usize, TagKind, u64), StreamCodec>>,
    /// Sparse-frame codec state per `(dst, kind, stream)` — dense-length
    /// reference/residual arrays plus the per-lane primed bitmap (see
    /// [`wire::SparseStreamCodec`]). Separate map: a sparse stream's
    /// state is indexed by dense coordinate, not frame position.
    sparse_codecs: Mutex<HashMap<(usize, TagKind, u64), wire::SparseStreamCodec>>,
    /// In-order release clamp of the reliable streams under faults: the
    /// latest delivery deadline enqueued per `(dst, kind)`. A frame
    /// delayed by retransmit backoff holds every later frame of the
    /// same stream behind it (TCP-style head-of-line blocking), so
    /// recovery delay propagates honestly instead of being absorbed by
    /// out-of-order delivery. Untouched when the fault plan is
    /// inactive.
    release: Mutex<HashMap<(usize, TagKind), Instant>>,
    /// Receiver-side decode seconds accumulated (as nanos) across every
    /// message this endpoint has received since the last
    /// [`Endpoint::take_decode_secs`] drain.
    decode_nanos: AtomicU64,
}

impl Endpoint {
    pub fn id(&self) -> usize {
        self.id
    }

    pub fn nodes(&self) -> usize {
        self.net.nodes()
    }

    /// Non-blocking send (MPI `Isend`): stamps a delivery deadline from
    /// the latency model and enqueues at the destination. This is the
    /// *exact* path — control payloads (votes, barriers, convergence
    /// decisions) must never be quantized, or nodes could disagree on
    /// lock-step stopping. Under a [`FaultPlan`] this is a *reliable*
    /// stream: dropped attempts are retransmitted (backoff-priced into
    /// the delivery deadline and the byte counters), so the frame
    /// always arrives.
    pub fn send(&self, dst: usize, kind: TagKind, tag: u64, payload: Vec<f64>, sent_iter: u64) {
        let bytes = wire::f64_frame_bytes(payload.len());
        self.enqueue(dst, kind, tag, bytes, payload, Vec::new(), sent_iter, true);
    }

    /// Send through the fabric's wire codec on stream `stream` (a stable
    /// caller-chosen id: frames of one stream must carry the same
    /// logical quantity round after round, or DeltaF32 would difference
    /// unrelated content). Latency and the byte counters are priced on
    /// the *encoded* frame; the payload delivered is the decoder's
    /// reconstruction. With the default [`WireFormat::F64`] this is
    /// byte-identical to [`Endpoint::send`]. Reliable under faults,
    /// like [`Endpoint::send`].
    pub fn send_coded(
        &self,
        dst: usize,
        kind: TagKind,
        tag: u64,
        stream: u64,
        payload: Vec<f64>,
        sent_iter: u64,
    ) {
        self.send_coded_class(dst, kind, tag, stream, payload, sent_iter, true);
    }

    /// [`Endpoint::send_coded`] on a *latest-wins* stream (async duals,
    /// fleet probes/commands, async-star chunks): the next send
    /// supersedes this frame, so under a [`FaultPlan`] a dropped or
    /// reordered frame is not retransmitted — it is lost (priced and
    /// counted, never delivered) and a DeltaF32 stream re-keys so the
    /// next delivered frame is an absolute keyframe and reconstruction
    /// never diverges.
    pub fn send_coded_latest(
        &self,
        dst: usize,
        kind: TagKind,
        tag: u64,
        stream: u64,
        payload: Vec<f64>,
        sent_iter: u64,
    ) {
        self.send_coded_class(dst, kind, tag, stream, payload, sent_iter, false);
    }

    #[allow(clippy::too_many_arguments)]
    fn send_coded_class(
        &self,
        dst: usize,
        kind: TagKind,
        tag: u64,
        stream: u64,
        payload: Vec<f64>,
        sent_iter: u64,
        reliable: bool,
    ) {
        let (bytes, payload) = if self.net.wire == WireFormat::F64 {
            (wire::f64_frame_bytes(payload.len()), payload)
        } else {
            let mut codecs = self.codecs.lock().unwrap();
            let codec = codecs
                .entry((dst, kind, stream))
                .or_insert_with(|| {
                    StreamCodec::with_keyframe_every(self.net.wire, self.net.keyframe_every)
                });
            let enc = codec.encode(payload);
            (enc.bytes, enc.payload)
        };
        let delivered = self.enqueue(dst, kind, tag, bytes, payload, Vec::new(), sent_iter, reliable);
        if !delivered && self.net.wire != WireFormat::F64 {
            // The receiver never saw this frame: force the next frame
            // of the stream to an absolute keyframe.
            if let Some(codec) = self.codecs.lock().unwrap().get_mut(&(dst, kind, stream)) {
                codec.rekey();
            }
        }
    }

    /// Sparse coordinate-update send (`--exchange greedy`): `values[i]`
    /// is the new absolute value at slice position `indices[i]`
    /// (sorted, strictly increasing, `< dense_len`). Values ride the
    /// fabric's wire codec through a per-stream [`wire::SparseStreamCodec`]
    /// (dense-coordinate error feedback); indices are priced as
    /// delta-varint-packed bytes on top of the value frame. Reliable
    /// under faults, like [`Endpoint::send_coded`].
    #[allow(clippy::too_many_arguments)]
    pub fn send_sparse_coded(
        &self,
        dst: usize,
        kind: TagKind,
        tag: u64,
        stream: u64,
        indices: Vec<u32>,
        values: Vec<f64>,
        dense_len: usize,
        sent_iter: u64,
    ) {
        self.send_sparse_class(dst, kind, tag, stream, indices, values, dense_len, sent_iter, true);
    }

    /// [`Endpoint::send_sparse_coded`] on a latest-wins stream (async
    /// greedy duals): a lost frame is never retransmitted — the codec
    /// re-keys (clears its primed lanes) so the next delivered frame
    /// carrying those coordinates is sent absolute.
    #[allow(clippy::too_many_arguments)]
    pub fn send_sparse_coded_latest(
        &self,
        dst: usize,
        kind: TagKind,
        tag: u64,
        stream: u64,
        indices: Vec<u32>,
        values: Vec<f64>,
        dense_len: usize,
        sent_iter: u64,
    ) {
        self.send_sparse_class(dst, kind, tag, stream, indices, values, dense_len, sent_iter, false);
    }

    #[allow(clippy::too_many_arguments)]
    fn send_sparse_class(
        &self,
        dst: usize,
        kind: TagKind,
        tag: u64,
        stream: u64,
        indices: Vec<u32>,
        values: Vec<f64>,
        dense_len: usize,
        sent_iter: u64,
        reliable: bool,
    ) {
        debug_assert!(indices.len() == values.len());
        let index_bytes = wire::sparse_index_bytes(&indices);
        let (bytes, payload) = if self.net.wire == WireFormat::F64 {
            (index_bytes + wire::f64_frame_bytes(values.len()), values)
        } else {
            let mut codecs = self.sparse_codecs.lock().unwrap();
            let codec = codecs.entry((dst, kind, stream)).or_insert_with(|| {
                wire::SparseStreamCodec::with_keyframe_every(self.net.wire, self.net.keyframe_every)
            });
            let enc = codec.encode(&indices, values, dense_len);
            (index_bytes + enc.bytes, enc.payload)
        };
        let delivered = self.enqueue(dst, kind, tag, bytes, payload, indices, sent_iter, reliable);
        if !delivered && self.net.wire != WireFormat::F64 {
            if let Some(codec) = self.sparse_codecs.lock().unwrap().get_mut(&(dst, kind, stream)) {
                codec.rekey();
            }
        }
    }

    /// Returns whether the frame was delivered (always true on reliable
    /// streams; false when a latest-wins frame is lost to the fault
    /// schedule).
    #[allow(clippy::too_many_arguments)]
    fn enqueue(
        &self,
        dst: usize,
        kind: TagKind,
        tag: u64,
        frame_bytes: usize,
        payload: Vec<f64>,
        indices: Vec<u32>,
        sent_iter: u64,
        reliable: bool,
    ) -> bool {
        let bytes = frame_bytes + MSG_HEADER_BYTES;
        let faulty = self.net.faults.is_active();
        let (seq, faults) = if faulty {
            let seq = self.net.next_link_seq(self.id, dst);
            (seq, self.net.faults.roll(self.id, dst, seq))
        } else {
            (0, FrameFaults::none())
        };
        let mut delay = {
            let mut rng = self.rng.lock().unwrap();
            self.net.latency.delay_secs(bytes, &mut rng)
        };
        // The surviving attempt's traffic.
        self.net.kind_bytes[kind.index()].fetch_add(bytes as u64, Ordering::Relaxed);
        self.net.kind_msgs[kind.index()].fetch_add(1, Ordering::Relaxed);
        let mut lost = false;
        // Link-quality-adaptive retransmit timer: read the estimate
        // *before* this frame's own delay is observed, like a real ARQ
        // sender whose timer is armed from past traffic only. The
        // deterministic transfer estimate is the cold-start prior.
        let rto = if faulty {
            self.net
                .link_rto(self.id, dst, faults::rto_secs(&self.net.latency, bytes))
        } else {
            0.0
        };
        if faulty {
            if faults.spike_mult > 1.0 {
                self.net.n_spikes.fetch_add(1, Ordering::Relaxed);
                delay *= faults.spike_mult;
            }
            if faults.drops > 0 {
                self.net.n_drops.fetch_add(faults.drops as u64, Ordering::Relaxed);
                if reliable {
                    // Fast-forward ARQ: price every failed attempt
                    // (frame out + nack back) and stretch the deadline
                    // by the accumulated exponential backoff.
                    self.net
                        .n_retransmits
                        .fetch_add(faults.drops as u64, Ordering::Relaxed);
                    let extra = (bytes + NACK_FRAME_BYTES) as u64 * faults.drops as u64;
                    self.net.kind_bytes[kind.index()].fetch_add(extra, Ordering::Relaxed);
                    self.net.kind_msgs[kind.index()]
                        .fetch_add(faults.drops as u64, Ordering::Relaxed);
                    delay += faults::backoff_secs(rto, faults.drops);
                } else {
                    lost = true;
                }
            }
            if faults.reordered {
                self.net.n_reorders.fetch_add(1, Ordering::Relaxed);
                if reliable {
                    // In-order delivery holds the frame one timeout.
                    delay += rto;
                } else {
                    // Would arrive already superseded.
                    lost = true;
                }
            }
            let straggler = self.net.faults.straggler_mult(self.id);
            if straggler > 1.0 {
                delay *= straggler;
            }
            // Karn's rule: only clean first-transmission deliveries
            // sample the timer — a retransmitted or reorder-held frame's
            // delay includes the backoff the timer itself decided, and
            // feeding that back would inflate the estimate unboundedly.
            // At this point `delay` carries the spike and straggler
            // multipliers but no backoff/hold terms, which is exactly
            // the delivery delay a live sender would measure.
            if faults.drops == 0 && !faults.reordered && !lost {
                self.net.observe_link_delay(self.id, dst, delay);
            }
        }
        if lost {
            return false;
        }
        let mut deliver_at = Instant::now() + Duration::from_secs_f64(delay);
        if faulty && reliable {
            // In-order release clamp: never deliver before an earlier
            // frame of the same (dst, kind) stream.
            let mut release = self.release.lock().unwrap();
            let slot = release.entry((dst, kind)).or_insert(deliver_at);
            deliver_at = deliver_at.max(*slot);
            *slot = deliver_at;
        }
        let msg = Message {
            src: self.id,
            kind,
            tag,
            payload,
            indices,
            sent_iter,
            seq,
            decode_secs: self.net.latency.decode_secs(bytes),
            deliver_at,
        };
        let dup = if faulty && faults.duplicated {
            self.net.n_dups.fetch_add(1, Ordering::Relaxed);
            self.net.kind_bytes[kind.index()].fetch_add(bytes as u64, Ordering::Relaxed);
            self.net.kind_msgs[kind.index()].fetch_add(1, Ordering::Relaxed);
            let mut copy = msg.clone();
            copy.deliver_at = deliver_at + Duration::from_secs_f64(rto);
            Some(copy)
        } else {
            None
        };
        let inbox = &self.net.inboxes[dst];
        {
            let mut queue = inbox.queue.lock().unwrap();
            queue.push(msg);
            if let Some(copy) = dup {
                queue.push(copy);
            }
            // Bumped under the lock so a wait_traffic holding it cannot
            // observe the push without the bump.
            inbox.seq.fetch_add(1, Ordering::Release);
        }
        inbox.signal.notify_all();
        true
    }

    /// Record a received frame's decode cost; drained by
    /// [`Endpoint::take_decode_secs`].
    fn account_decode(&self, m: &Message) {
        if m.decode_secs > 0.0 {
            self.decode_nanos
                .fetch_add((m.decode_secs * 1e9) as u64, Ordering::Relaxed);
        }
    }

    /// Drain the decode seconds accumulated across every message
    /// received since the last call. Coordinators fold this into their
    /// **comp** bucket once per iteration — dequantizing frames is CPU
    /// work the receiver pays, not network time.
    pub fn take_decode_secs(&self) -> f64 {
        self.decode_nanos.swap(0, Ordering::Relaxed) as f64 * 1e-9
    }

    /// Current inbox arrival count — pair with
    /// [`Endpoint::wait_traffic`]: read it *before* draining, drain,
    /// and if nothing useful arrived, wait for the count to move.
    pub fn inbox_seq(&self) -> u64 {
        self.net.inboxes[self.id].seq.load(Ordering::Acquire)
    }

    /// Park until inbox traffic moves past `seen`: returns the fresh
    /// arrival count as soon as any message is enqueued after the
    /// caller read `seen`, when a message queued but *undeliverable at
    /// call entry* passes its delivery deadline, or after `cap`. The
    /// async coordinators' staleness loops block here instead of
    /// polling with fixed busy-sleeps. Deadlines are filtered at entry
    /// so lingering deliverable-but-unmatched traffic (e.g. fleet
    /// probes awaiting their drain point) cannot turn the wait into a
    /// spin.
    pub fn wait_traffic(&self, seen: u64, cap: Duration) -> u64 {
        let inbox = &self.net.inboxes[self.id];
        let entry = Instant::now();
        let mut queue = inbox.queue.lock().unwrap();
        let next_deadline = queue
            .iter()
            .filter(|m| m.deliver_at > entry)
            .map(|m| m.deliver_at)
            .min();
        let until = match next_deadline {
            Some(d) => d.min(entry + cap),
            None => entry + cap,
        };
        loop {
            // Read under the lock: an enqueue bumps seq while holding
            // it, so a bump cannot slip between this check and the wait.
            let seq = inbox.seq.load(Ordering::Relaxed);
            if seq != seen {
                return seq;
            }
            let now = Instant::now();
            if now >= until {
                return seq;
            }
            let (q, _timeout) = inbox.signal.wait_timeout(queue, until - now).unwrap();
            queue = q;
        }
    }

    /// Blocking receive of the first matching message (MPI `Recv`):
    /// blocks until a `(src, kind, tag)` match exists *and* its delivery
    /// deadline has passed — the deadline sleep is what makes simulated
    /// network time real wall time.
    pub fn recv_blocking(&self, src: usize, kind: TagKind, tag: u64) -> Message {
        self.recv_where(kind, tag, |m| m.src == src, None)
            .expect("unbounded receive cannot time out")
    }

    /// Blocking receive of the first *deliverable* `(kind, tag)` match
    /// from any source still flagged in `pending` — the streamed-
    /// exchange primitive: slices are consumed in delivery order, so the
    /// caller's decode + partial compute hide behind the transfers still
    /// in flight instead of waiting out the slowest peer first.
    pub fn recv_any_blocking(&self, pending: &[bool], kind: TagKind, tag: u64) -> Message {
        self.recv_where(kind, tag, |m| pending.get(m.src).copied().unwrap_or(false), None)
            .expect("unbounded receive cannot time out")
    }

    /// [`Endpoint::recv_blocking`] with a deadline: `None` after
    /// `timeout` without a deliverable match — the peer-death detection
    /// primitive (the coordinators strike a peer after R consecutive
    /// timeouts, see [`super::faults::Recovery`]).
    pub fn recv_timeout(
        &self,
        src: usize,
        kind: TagKind,
        tag: u64,
        timeout: Duration,
    ) -> Option<Message> {
        self.recv_where(kind, tag, |m| m.src == src, Some(Instant::now() + timeout))
    }

    /// [`Endpoint::recv_any_blocking`] with a deadline (see
    /// [`Endpoint::recv_timeout`]).
    pub fn recv_any_timeout(
        &self,
        pending: &[bool],
        kind: TagKind,
        tag: u64,
        timeout: Duration,
    ) -> Option<Message> {
        self.recv_where(
            kind,
            tag,
            |m| pending.get(m.src).copied().unwrap_or(false),
            Some(Instant::now() + timeout),
        )
    }

    fn recv_where(
        &self,
        kind: TagKind,
        tag: u64,
        matches: impl Fn(&Message) -> bool,
        deadline: Option<Instant>,
    ) -> Option<Message> {
        // The stall watchdog only arms unbounded receives — a timeout
        // receive already has a bounded wait and a live failure path.
        let stall = match deadline {
            None => stall_limit().map(|d| Instant::now() + d),
            Some(_) => None,
        };
        let sweep_dups = self.net.faults.is_active();
        let inbox = &self.net.inboxes[self.id];
        let mut queue = inbox.queue.lock().unwrap();
        loop {
            let now = Instant::now();
            let mut earliest: Option<Instant> = None;
            let mut take_idx = None;
            for (i, m) in queue.iter().enumerate() {
                if m.kind == kind && m.tag == tag && matches(m) {
                    if m.deliver_at <= now {
                        take_idx = Some(i);
                        break;
                    }
                    earliest = Some(match earliest {
                        Some(e) => e.min(m.deliver_at),
                        None => m.deliver_at,
                    });
                }
            }
            if let Some(i) = take_idx {
                let m = queue.swap_remove(i);
                if sweep_dups {
                    // Discard queued duplicate copies of the taken
                    // frame (same link sequence number) — a real
                    // receiver decodes and drops them.
                    let mut j = 0;
                    while j < queue.len() {
                        let d = &queue[j];
                        if d.src == m.src && d.kind == m.kind && d.tag == m.tag && d.seq == m.seq
                        {
                            let d = queue.swap_remove(j);
                            self.account_decode(&d);
                        } else {
                            j += 1;
                        }
                    }
                }
                self.account_decode(&m);
                return Some(m);
            }
            if let Some(d) = deadline {
                if now >= d {
                    return None;
                }
            }
            if let Some(s) = stall {
                if now >= s {
                    let dump: Vec<String> = queue
                        .iter()
                        .map(|m| {
                            format!(
                                "src={} kind={} tag={} seq={} sent_iter={} due_in={:.3}s",
                                m.src,
                                m.kind.name(),
                                m.tag,
                                m.seq,
                                m.sent_iter,
                                m.deliver_at.saturating_duration_since(now).as_secs_f64()
                            )
                        })
                        .collect();
                    panic!(
                        "FEDSINK_STALL_SECS watchdog: node {} stalled waiting for \
                         (kind={}, tag={}); pending inbox [{}]",
                        self.id,
                        kind.name(),
                        tag,
                        dump.join("; ")
                    );
                }
            }
            // Sleep until the earliest matching deadline, or until a new
            // message arrives — capped by the receive deadline and the
            // stall watchdog so both stay responsive.
            let mut wait = earliest
                .map(|e| e.saturating_duration_since(now))
                .unwrap_or(Duration::from_millis(50));
            if let Some(d) = deadline {
                wait = wait.min(d.saturating_duration_since(now));
            }
            if let Some(s) = stall {
                wait = wait.min(s.saturating_duration_since(now));
            }
            let (q, _timeout) = inbox
                .signal
                .wait_timeout(queue, wait.max(Duration::from_micros(20)))
                .unwrap();
            queue = q;
        }
    }

    /// Latest-wins non-blocking receive (async protocol): drains every
    /// *deliverable* `(src, kind, tag)` match and returns the one with
    /// the highest `sent_iter`, or `None` if nothing has arrived yet.
    pub fn try_recv_latest(&self, src: usize, kind: TagKind, tag: u64) -> Option<Message> {
        let inbox = &self.net.inboxes[self.id];
        let mut queue = inbox.queue.lock().unwrap();
        let now = Instant::now();
        let mut best: Option<Message> = None;
        let mut i = 0;
        while i < queue.len() {
            let m = &queue[i];
            if m.src == src && m.kind == kind && m.tag == tag && m.deliver_at <= now {
                let m = queue.swap_remove(i);
                // Superseded frames were still decoded on arrival —
                // latest-wins drops their *content*, not their cost.
                self.account_decode(&m);
                best = match best {
                    Some(b) if b.sent_iter >= m.sent_iter => Some(b),
                    _ => Some(m),
                };
            } else {
                i += 1;
            }
        }
        best
    }

    /// Non-blocking drain of *every* deliverable `(src, kind, tag)`
    /// match, returned in ascending `sent_iter` order — the sparse-frame
    /// drain: unlike [`Endpoint::try_recv_latest`], older frames are not
    /// discarded, because each sparse frame may carry coordinates absent
    /// from later frames and the receiver scatters them all (oldest
    /// first, so a re-selected coordinate lands on its newest value).
    pub fn try_recv_all(&self, src: usize, kind: TagKind, tag: u64) -> Vec<Message> {
        let sweep_dups = self.net.faults.is_active();
        let inbox = &self.net.inboxes[self.id];
        let mut queue = inbox.queue.lock().unwrap();
        let now = Instant::now();
        let mut out: Vec<Message> = Vec::new();
        let mut i = 0;
        while i < queue.len() {
            let m = &queue[i];
            if m.src == src && m.kind == kind && m.tag == tag && m.deliver_at <= now {
                let m = queue.swap_remove(i);
                self.account_decode(&m);
                // Drop duplicate copies (same link sequence) like the
                // blocking path — decode-priced, content discarded.
                if !sweep_dups || !out.iter().any(|o: &Message| o.seq == m.seq) {
                    out.push(m);
                }
            } else {
                i += 1;
            }
        }
        out.sort_by_key(|m| m.sent_iter);
        out
    }

    /// Count of queued (not necessarily deliverable) messages — tests.
    pub fn pending(&self) -> usize {
        self.net.inboxes[self.id].queue.lock().unwrap().len()
    }
}
