//! Mailbox fabric: per-node inboxes with delivery deadlines.

use super::LatencyModel;
use crate::rng::{child_seed, Rng};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Message kinds — the Sinkhorn protocol exchanges the two scaling
/// vectors, small control payloads, and (fleet-absorption runs) the
/// reference-dual synchronization traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TagKind {
    /// u-slice broadcast.
    U,
    /// v-slice broadcast.
    V,
    /// Control (barriers, convergence votes, scatter/gather frames).
    Ctl,
    /// Fleet-synchronized absorption: slice-local drift probes to the
    /// coordinator and the reference-dual `ḡ` broadcast back. Priced by
    /// the same α–β latency model as every other message (`α` base +
    /// `β`·bytes), so the protocol's extra per-iteration term shows up
    /// honestly in the comm-time buckets the paper reports.
    Gref,
}

/// One in-flight message.
#[derive(Clone, Debug)]
pub struct Message {
    pub src: usize,
    pub kind: TagKind,
    /// Protocol round or collective id — keeps rounds from crossing.
    pub tag: u64,
    pub payload: Vec<f64>,
    /// Sender's local iteration when it sent (staleness accounting).
    pub sent_iter: u64,
    /// Wall-clock deadline before which the receiver may not observe it.
    deliver_at: Instant,
}

#[derive(Default)]
struct Inbox {
    queue: Mutex<Vec<Message>>,
    signal: Condvar,
}

/// The shared fabric: `nodes` inboxes + the latency model.
pub struct SimNet {
    inboxes: Vec<Inbox>,
    latency: LatencyModel,
    seed: u64,
    /// Total payload bytes pushed through the fabric (diagnostics).
    bytes_sent: Mutex<u64>,
}

impl SimNet {
    pub fn new(nodes: usize, latency: LatencyModel, seed: u64) -> Self {
        Self {
            inboxes: (0..nodes).map(|_| Inbox::default()).collect(),
            latency,
            seed,
            bytes_sent: Mutex::new(0),
        }
    }

    pub fn nodes(&self) -> usize {
        self.inboxes.len()
    }

    pub fn bytes_sent(&self) -> u64 {
        *self.bytes_sent.lock().unwrap()
    }

    /// Create the handle node `id` uses to talk to the fabric. Each
    /// endpoint carries its own jitter RNG stream so runs are
    /// deterministic given (seed, thread schedule).
    pub fn endpoint(self: &std::sync::Arc<Self>, id: usize) -> Endpoint {
        assert!(id < self.nodes());
        Endpoint {
            net: self.clone(),
            id,
            rng: Mutex::new(Rng::seed_from(child_seed(self.seed, id as u64))),
        }
    }
}

/// A node's handle to the fabric.
pub struct Endpoint {
    net: std::sync::Arc<SimNet>,
    id: usize,
    rng: Mutex<Rng>,
}

impl Endpoint {
    pub fn id(&self) -> usize {
        self.id
    }

    pub fn nodes(&self) -> usize {
        self.net.nodes()
    }

    /// Non-blocking send (MPI `Isend`): stamps a delivery deadline from
    /// the latency model and enqueues at the destination.
    pub fn send(&self, dst: usize, kind: TagKind, tag: u64, payload: Vec<f64>, sent_iter: u64) {
        let bytes = payload.len() * std::mem::size_of::<f64>() + 64; // + header
        let delay = {
            let mut rng = self.rng.lock().unwrap();
            self.net.latency.delay_secs(bytes, &mut rng)
        };
        *self.net.bytes_sent.lock().unwrap() += bytes as u64;
        let msg = Message {
            src: self.id,
            kind,
            tag,
            payload,
            sent_iter,
            deliver_at: Instant::now() + Duration::from_secs_f64(delay),
        };
        let inbox = &self.net.inboxes[dst];
        inbox.queue.lock().unwrap().push(msg);
        inbox.signal.notify_all();
    }

    /// Blocking receive of the first matching message (MPI `Recv`):
    /// blocks until a `(src, kind, tag)` match exists *and* its delivery
    /// deadline has passed — the deadline sleep is what makes simulated
    /// network time real wall time.
    pub fn recv_blocking(&self, src: usize, kind: TagKind, tag: u64) -> Message {
        let inbox = &self.net.inboxes[self.id];
        let mut queue = inbox.queue.lock().unwrap();
        loop {
            let now = Instant::now();
            let mut earliest: Option<Instant> = None;
            let mut take_idx = None;
            for (i, m) in queue.iter().enumerate() {
                if m.src == src && m.kind == kind && m.tag == tag {
                    if m.deliver_at <= now {
                        take_idx = Some(i);
                        break;
                    }
                    earliest = Some(match earliest {
                        Some(e) => e.min(m.deliver_at),
                        None => m.deliver_at,
                    });
                }
            }
            if let Some(i) = take_idx {
                return queue.swap_remove(i);
            }
            // Sleep until the earliest matching deadline, or until a new
            // message arrives.
            let wait = earliest
                .map(|e| e.saturating_duration_since(now))
                .unwrap_or(Duration::from_millis(50));
            let (q, _timeout) = inbox
                .signal
                .wait_timeout(queue, wait.max(Duration::from_micros(20)))
                .unwrap();
            queue = q;
        }
    }

    /// Latest-wins non-blocking receive (async protocol): drains every
    /// *deliverable* `(src, kind, tag)` match and returns the one with
    /// the highest `sent_iter`, or `None` if nothing has arrived yet.
    pub fn try_recv_latest(&self, src: usize, kind: TagKind, tag: u64) -> Option<Message> {
        let inbox = &self.net.inboxes[self.id];
        let mut queue = inbox.queue.lock().unwrap();
        let now = Instant::now();
        let mut best: Option<Message> = None;
        let mut i = 0;
        while i < queue.len() {
            let m = &queue[i];
            if m.src == src && m.kind == kind && m.tag == tag && m.deliver_at <= now {
                let m = queue.swap_remove(i);
                best = match best {
                    Some(b) if b.sent_iter >= m.sent_iter => Some(b),
                    _ => Some(m),
                };
            } else {
                i += 1;
            }
        }
        best
    }

    /// Count of queued (not necessarily deliverable) messages — tests.
    pub fn pending(&self) -> usize {
        self.net.inboxes[self.id].queue.lock().unwrap().len()
    }
}
