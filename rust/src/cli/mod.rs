//! Declarative CLI flag parser (no `clap` in the offline image).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help`. Each subcommand of the
//! `fedsink` launcher declares an [`ArgSpec`] and receives a typed
//! [`Parsed`] view.

use std::collections::BTreeMap;
use std::fmt;

/// One declared flag.
#[derive(Clone, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub value_name: Option<&'static str>,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// A subcommand's argument specification.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    pub flags: Vec<Flag>,
}

impl ArgSpec {
    pub fn new() -> Self {
        Self::default()
    }

    /// Flag taking a value, with default.
    pub fn opt(
        mut self,
        name: &'static str,
        value_name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.flags.push(Flag { name, value_name: Some(value_name), default: Some(default), help });
        self
    }

    /// Flag taking a value, no default (optional).
    pub fn opt_req(
        mut self,
        name: &'static str,
        value_name: &'static str,
        help: &'static str,
    ) -> Self {
        self.flags.push(Flag { name, value_name: Some(value_name), default: None, help });
        self
    }

    /// Boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, value_name: None, default: None, help });
        self
    }

    pub fn usage(&self, cmd: &str) -> String {
        let mut out = format!("usage: fedsink {cmd} [flags]\n\nflags:\n");
        for f in &self.flags {
            let left = match f.value_name {
                Some(v) => format!("  --{} <{}>", f.name, v),
                None => format!("  --{}", f.name),
            };
            let default = f
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("{left:<28}{}{}\n", f.help, default));
        }
        out.push_str("  --help                    show this message\n");
        out
    }

    /// Parse `args` (after the subcommand name).
    pub fn parse(&self, cmd: &str, args: &[String]) -> Result<Parsed, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut switches: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        for f in &self.flags {
            if let Some(d) = f.default {
                values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError::Help(self.usage(cmd)));
            }
            if let Some(name) = arg.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let flag = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::Unknown(format!("--{name}"), self.usage(cmd)))?;
                if flag.value_name.is_some() {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(format!("--{name}")))?
                        }
                    };
                    values.insert(name.to_string(), v);
                } else {
                    switches.push(name.to_string());
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(Parsed { values, switches, positional })
    }
}

/// Parsed CLI arguments with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    pub values: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.typed(name, |s| s.parse().ok())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.typed(name, |s| s.parse().ok())
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.typed(name, |s| s.parse().ok())
    }

    /// Comma-separated list.
    pub fn get_list<T>(&self, name: &str, parse: impl Fn(&str) -> Option<T>) -> Result<Vec<T>, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(format!("--{name}")))?;
        raw.split(',')
            .map(|s| {
                parse(s.trim()).ok_or_else(|| {
                    CliError::BadValue(format!("--{name}"), s.trim().to_string())
                })
            })
            .collect()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    fn typed<T>(&self, name: &str, parse: impl Fn(&str) -> Option<T>) -> Result<T, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(format!("--{name}")))?;
        parse(raw).ok_or_else(|| CliError::BadValue(format!("--{name}"), raw.to_string()))
    }
}

/// CLI failure modes; `Help` carries the usage text (exit 0).
#[derive(Debug)]
pub enum CliError {
    Help(String),
    Unknown(String, String),
    MissingValue(String),
    BadValue(String, String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::Help(u) => write!(f, "{u}"),
            CliError::Unknown(flag, usage) => write!(f, "unknown flag {flag}\n\n{usage}"),
            CliError::MissingValue(flag) => write!(f, "flag {flag} requires a value"),
            CliError::BadValue(flag, v) => write!(f, "invalid value {v:?} for {flag}"),
        }
    }
}

impl std::error::Error for CliError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new()
            .opt("n", "SIZE", "256", "problem size")
            .opt("alpha", "A", "1.0", "damping")
            .opt_req("out", "PATH", "output file")
            .switch("verbose", "chatty")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse("t", &args(&[])).unwrap();
        assert_eq!(p.get_usize("n").unwrap(), 256);
        assert_eq!(p.get_f64("alpha").unwrap(), 1.0);
        assert!(p.get("out").is_none());
        assert!(!p.has("verbose"));
    }

    #[test]
    fn equals_and_space_forms() {
        let p = spec()
            .parse("t", &args(&["--n=512", "--alpha", "0.25", "--verbose"]))
            .unwrap();
        assert_eq!(p.get_usize("n").unwrap(), 512);
        assert_eq!(p.get_f64("alpha").unwrap(), 0.25);
        assert!(p.has("verbose"));
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(matches!(
            spec().parse("t", &args(&["--bogus"])),
            Err(CliError::Unknown(..))
        ));
    }

    #[test]
    fn help_short_circuits() {
        assert!(matches!(
            spec().parse("t", &args(&["--help"])),
            Err(CliError::Help(_))
        ));
    }

    #[test]
    fn list_parsing() {
        let spec = ArgSpec::new().opt("sizes", "LIST", "1,2,4", "sizes");
        let p = spec.parse("t", &args(&[])).unwrap();
        let v: Vec<usize> = p.get_list("sizes", |s| s.parse().ok()).unwrap();
        assert_eq!(v, vec![1, 2, 4]);
    }

    #[test]
    fn bad_typed_value() {
        let p = spec().parse("t", &args(&["--n", "abc"])).unwrap();
        assert!(matches!(p.get_usize("n"), Err(CliError::BadValue(..))));
    }
}
