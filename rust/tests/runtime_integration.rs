//! Integration: AOT artifacts through PJRT vs the native oracle.
//!
//! These tests need `artifacts/` (run `make artifacts`); they skip with a
//! message otherwise so `cargo test` stays green on a fresh checkout.
//! The whole suite is gated on the `xla-backend` feature — the `xla`
//! crate (and its PJRT C library) is unavailable in offline builds.

#![cfg(feature = "xla-backend")]

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::linalg::Mat;
use fedsink::net::LatencyModel;
use fedsink::rng::Rng;
use fedsink::runtime::{make_backend, ComputeBackend, NativeBackend, PjrtRuntime, Target};
use fedsink::sinkhorn::{CentralizedSolver, StopPolicy};
use fedsink::workload::ProblemSpec;

fn artifacts_dir() -> Option<String> {
    let dir = fedsink::config::default_artifacts_dir();
    std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (make artifacts)");
                return;
            }
        }
    };
}

fn sample(m: usize, n: usize, nh: usize, seed: u64) -> (Mat, Mat, Vec<f64>, Mat) {
    let mut rng = Rng::seed_from(seed);
    (
        Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng),
        Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng),
        (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect(),
        Mat::rand_uniform(m, nh, 0.1, 1.0, &mut rng),
    )
}

#[test]
fn xla_block_op_matches_native() {
    let dir = require_artifacts!();
    let xla = make_backend(BackendKind::Xla, &dir, 1).expect("xla backend");
    let native = NativeBackend::new(1);

    // (m, n, N) on the AOT grid.
    for &(m, n, nh) in &[(64usize, 64usize, 1usize), (32, 64, 1), (64, 64, 64), (128, 256, 1)] {
        let (a, x, t, u0) = sample(m, n, nh, 42 + m as u64);
        let mut op_x = xla.block_op(&a, Target::Vec(&t), u0.clone()).unwrap();
        let mut op_n = native.block_op(&a, Target::Vec(&t), u0.clone()).unwrap();
        for &alpha in &[1.0, 0.5] {
            let got = op_x.update(&x, alpha).clone();
            let want = op_n.update(&x, alpha).clone();
            assert!(got.allclose(&want, 1e-11), "update mismatch at ({m},{n},{nh})");
        }
        let got = op_x.marginal(&x, &u0);
        let want = op_n.marginal(&x, &u0);
        for h in 0..nh {
            assert!((got[h] - want[h]).abs() < 1e-10, "marginal at ({m},{n},{nh})[{h}]");
        }
    }
}

#[test]
fn xla_mat_target_matches_native() {
    let dir = require_artifacts!();
    let xla = make_backend(BackendKind::Xla, &dir, 1).expect("xla backend");
    let native = NativeBackend::new(1);
    let (a, x, _, u0) = sample(64, 64, 64, 7);
    let mut rng = Rng::seed_from(9);
    let tm = Mat::rand_uniform(64, 64, 0.1, 1.0, &mut rng);
    let mut op_x = xla.block_op(&a, Target::Mat(&tm), u0.clone()).unwrap();
    let mut op_n = native.block_op(&a, Target::Mat(&tm), u0.clone()).unwrap();
    let got = op_x.update(&x, 0.7).clone();
    let want = op_n.update(&x, 0.7).clone();
    assert!(got.allclose(&want, 1e-11));
}

#[test]
fn xla_matvec_matches_native() {
    let dir = require_artifacts!();
    let xla = make_backend(BackendKind::Xla, &dir, 1).expect("xla backend");
    let (a, x, t, u0) = sample(256, 256, 1, 3);
    let mut op = xla.block_op(&a, Target::Vec(&t), u0).unwrap();
    let got = op.matvec(&x).clone();
    let want = a.matmul(&x, 1);
    assert!(got.allclose(&want, 1e-11));
}

#[test]
fn off_grid_shape_falls_back_to_native() {
    let dir = require_artifacts!();
    let xla = make_backend(BackendKind::Xla, &dir, 1).expect("xla backend");
    // 17 × 23 is not on any AOT grid → silently served by the fallback.
    let (a, x, t, u0) = sample(17, 23, 2, 5);
    let mut op = xla.block_op(&a, Target::Vec(&t), u0).unwrap();
    let got = op.update(&x, 1.0).clone();
    let q = a.matmul(&x, 1);
    for i in 0..17 {
        for h in 0..2 {
            assert!((got[(i, h)] - t[i] / q[(i, h)]).abs() < 1e-12);
        }
    }
}

#[test]
fn pallas_and_xla_impl_artifacts_agree() {
    // The architecture requirement: the Pallas-lowered HLO (L1 kernels
    // inside the L2 graph) computes the same numbers as the plain-XLA
    // lowering, executed through PJRT.
    let dir = require_artifacts!();
    let rt = PjrtRuntime::shared(&dir).expect("runtime");
    let man = rt.manifest();
    let (m, n, nh) = (64, 64, 1);
    let e_xla = man.find_impl("client_update", "xla", m, n, nh, 0);
    let e_pal = man.find_impl("client_update", "pallas", m, n, nh, 0);
    let (Some(e_xla), Some(e_pal)) = (e_xla, e_pal) else {
        eprintln!("skipping: both impls not in manifest grid");
        return;
    };
    let (a, x, t, u0) = sample(m, n, nh, 11);
    let lits = vec![
        xla::Literal::vec1(t.as_slice()), // placeholder replaced below
    ];
    drop(lits);
    let mk = |data: &[f64], dims: &[i64]| {
        xla::Literal::vec1(data).reshape(dims).expect("reshape")
    };
    let inputs = vec![
        mk(a.as_slice(), &[m as i64, n as i64]),
        mk(x.as_slice(), &[n as i64, nh as i64]),
        xla::Literal::vec1(t.as_slice()),
        mk(u0.as_slice(), &[m as i64, nh as i64]),
        xla::Literal::vec1(&[0.7f64]),
    ];
    let out_xla = rt.run_entry(e_xla, &inputs).expect("xla artifact run");
    let out_pal = rt.run_entry(e_pal, &inputs).expect("pallas artifact run");
    assert_eq!(out_xla.len(), 1);
    assert_eq!(out_xla[0].len(), m * nh);
    for (a_, b_) in out_xla[0].iter().zip(&out_pal[0]) {
        assert!((a_ - b_).abs() < 1e-11, "{a_} vs {b_}");
    }
}

#[test]
fn sweep_artifact_runs_w_iterations() {
    let dir = require_artifacts!();
    let rt = PjrtRuntime::shared(&dir).expect("runtime");
    let Some(entry) = rt.manifest().find_w("sinkhorn_sweep", 64, 64, 1, 10) else {
        eprintln!("skipping: no sweep artifact");
        return;
    };
    let p = ProblemSpec::new(64).with_eps(0.5).build(13);
    let n = 64i64;
    let mk = |data: &[f64], dims: &[i64]| xla::Literal::vec1(data).reshape(dims).unwrap();
    let inputs = vec![
        mk(p.kernel().as_slice(), &[n, n]),
        xla::Literal::vec1(p.a.as_slice()),
        mk(p.b.as_slice(), &[n, 1]),
        mk(Mat::ones(64, 1).as_slice(), &[n, 1]),
        mk(Mat::ones(64, 1).as_slice(), &[n, 1]),
        xla::Literal::vec1(&[1.0f64]),
    ];
    let out = rt.run_entry(entry, &inputs).expect("sweep run");
    assert_eq!(out.len(), 2, "sweep returns (u, v)");
    // Compare against 10 native iterations.
    let mut u = vec![1.0; 64];
    let mut v = vec![1.0; 64];
    for _ in 0..10 {
        for i in 0..64 {
            let q: f64 = (0..64).map(|j| p.kernel()[(i, j)] * v[j]).sum();
            u[i] = p.a[i] / q;
        }
        for j in 0..64 {
            let r: f64 = (0..64).map(|i| p.kernel()[(i, j)] * u[i]).sum();
            v[j] = p.b[(j, 0)] / r;
        }
    }
    for i in 0..64 {
        assert!((out[0][i] - u[i]).abs() < 1e-9 * u[i].abs().max(1.0), "u[{i}]");
        assert!((out[1][i] - v[i]).abs() < 1e-9 * v[i].abs().max(1.0), "v[{i}]");
    }
}

#[test]
fn federated_solve_on_xla_backend_matches_native() {
    let dir = require_artifacts!();
    let p = ProblemSpec::new(64).with_eps(0.5).build(17);
    let policy = StopPolicy { threshold: 1e-11, max_iters: 2000, ..Default::default() };
    let mk_cfg = |backend| SolveConfig {
        variant: Variant::SyncA2A,
        backend,
        clients: 4,
        net: LatencyModel::zero(),
        artifacts_dir: dir.clone(),
        ..Default::default()
    };
    let out_x = fedsink::coordinator::run_federated(&p, &mk_cfg(BackendKind::Xla), policy, false);
    let out_n =
        fedsink::coordinator::run_federated(&p, &mk_cfg(BackendKind::Native), policy, false);
    assert!(out_x.converged && out_n.converged);
    assert!(out_x.state.u.allclose(&out_n.state.u, 1e-9));
    assert!(out_x.state.v.allclose(&out_n.state.v, 1e-9));
}

#[test]
fn centralized_solver_works_on_xla_backend() {
    let dir = require_artifacts!();
    let be = make_backend(BackendKind::Xla, &dir, 1).unwrap();
    let p = ProblemSpec::new(256).with_eps(0.5).build(19);
    let out = CentralizedSolver::new(be).solve(
        &p,
        StopPolicy { threshold: 1e-11, max_iters: 2000, ..Default::default() },
        1.0,
    );
    assert!(out.converged());
    let (ea, eb) = fedsink::sinkhorn::full_marginal_errors(&p, &out.state, 0);
    assert!(ea < 1e-9 && eb < 1e-9, "({ea}, {eb})");
}
