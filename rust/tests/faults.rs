//! Lossy-fabric integrity pins: fault determinism, exactness under
//! recovery, and crash-never-hangs.
//!
//! The fault layer's contract has three legs. (1) Schedules are pure in
//! `(seed, src, dst, seq)`, so a faulted run is as replayable as a
//! lossless one — at every thread count, extending the `pool_parity.rs`
//! bit-identity discipline to the fault layer. (2) Recovery is *exact*
//! for the lock-step protocols: the reliable streams retransmit until
//! delivery, so drop/dup/reorder faults change timing and traffic
//! counters but never a payload byte — sync iterates at the F64 wire
//! must match the lossless baseline bit for bit, with the same
//! iteration counts. (The async protocols are timing-nondeterministic
//! by design — latest-wins frames are genuinely lost — so there the pin
//! is convergence through the lossy fabric, not bit equality.)
//! (3) Crash injection degrades, never hangs: every blocking wait in a
//! resilient run is bounded by the recovery policy, pinned here by a
//! hard-timeout harness that fails the test instead of wedging it.

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::net::{FaultPlan, LatencyModel, LinkFault, NodeFault, NodeLoss, Recovery};
use fedsink::sinkhorn::{StopPolicy, StopReason};
use fedsink::testkit::run_with_timeout;
use fedsink::workload::ProblemSpec;

/// The pinned thread counts: serial, the smallest parallel split, and
/// the machine's full width (deduplicated on narrow CI runners).
fn thread_counts() -> Vec<usize> {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut ts = vec![1, 2, avail];
    ts.sort_unstable();
    ts.dedup();
    ts
}

/// A busy lossy link: drops force retransmits on the reliable streams,
/// dups and reorders exercise the receive-side filters, spikes ride the
/// latency pricing.
fn lossy_link() -> LinkFault {
    LinkFault { drop_prob: 0.15, dup_prob: 0.05, reorder_prob: 0.05, delay_spike: (0.02, 4.0) }
}

fn lossy_plan(seed: u64) -> FaultPlan {
    FaultPlan { seed, default_link: lossy_link(), ..FaultPlan::none() }
}

/// Crash `node` (its local iteration counter hits `at`), links clean.
fn crash_plan(node: usize, at: u64) -> FaultPlan {
    let mut plan = FaultPlan::none();
    plan.nodes.insert(node, NodeFault { crash_at_iter: Some(at), ..NodeFault::default() });
    plan
}

/// Tight recovery budget so struck peers are declared dead in ~0.1 s.
fn fast_recovery(on_node_loss: NodeLoss) -> Recovery {
    Recovery { recv_timeout_secs: 0.05, strikes: 2, on_node_loss }
}

fn cfg(variant: Variant, faults: FaultPlan, recovery: Recovery) -> SolveConfig {
    SolveConfig {
        variant,
        backend: BackendKind::Native,
        clients: 2,
        alpha: if matches!(variant, Variant::AsyncA2A | Variant::AsyncStar) { 0.5 } else { 1.0 },
        net: LatencyModel::zero(),
        compute_threads: 2,
        seed: 11,
        faults,
        recovery,
        ..Default::default()
    }
}

fn assert_bit_identical(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(g.to_bits() == w.to_bits(), "{what}: index {i} differs: got {g:e}, want {w:e}");
    }
}

#[test]
fn fault_schedules_replay_exactly_from_the_seed() {
    // Pure in (seed, src, dst, seq): two plans with the same seed roll
    // identical schedules over an exhaustive sweep; a different seed
    // diverges somewhere in the same sweep.
    let (a, b, c) = (lossy_plan(7), lossy_plan(7), lossy_plan(8));
    let mut diverged = false;
    for src in 0..3 {
        for dst in 0..3 {
            for seq in 0..200u64 {
                assert_eq!(a.roll(src, dst, seq), b.roll(src, dst, seq), "same seed must replay");
                diverged |= a.roll(src, dst, seq) != c.roll(src, dst, seq);
            }
        }
    }
    assert!(diverged, "different seeds should produce different schedules");
}

#[test]
fn adaptive_rto_samples_clean_deliveries_only() {
    // Karn's rule at the fabric level: a link whose every attempt is
    // dropped (and so retransmitted) never samples the adaptive
    // retransmit timer — its delivery delays include the backoff the
    // timer itself decided — while clean deliveries on a healthy link
    // of the same faulted fabric prime the EWMA.
    use fedsink::net::{SimNet, TagKind};
    use std::sync::Arc;
    let mut plan = FaultPlan::none();
    plan.links.insert((0, 1), LinkFault { drop_prob: 1.0, ..LinkFault::none() });
    let net = Arc::new(SimNet::new(3, LatencyModel::zero(), 1).with_faults(plan));
    let (e0, e1, e2) = (net.endpoint(0), net.endpoint(1), net.endpoint(2));
    for i in 0..8u64 {
        e0.send(1, TagKind::Ctl, i, vec![i as f64], i);
        e0.send(2, TagKind::Ctl, i, vec![i as f64], i);
    }
    for i in 0..8u64 {
        e1.recv_blocking(0, TagKind::Ctl, i);
        e2.recv_blocking(0, TagKind::Ctl, i);
    }
    assert!(net.traffic().retransmits > 0, "the (0,1) drops must have fired");
    assert!(!net.link_rtt(0, 1).primed, "retransmitted frames must not sample the timer");
    let rtt = net.link_rtt(0, 2);
    assert!(rtt.primed && rtt.srtt >= 0.0 && rtt.rttvar >= 0.0);
    assert!(!net.link_rtt(1, 0).primed, "links that never sent stay on the prior");
}

#[test]
fn faulted_sync_iterates_are_bit_identical_at_every_thread_count() {
    // The pool_parity discipline extended to the fault layer: one
    // faulted sync run, replayed at thread counts {1, 2, width} and
    // twice at the same count, always lands on the same iterates.
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-11, max_iters: 1500, ..Default::default() };
    let run = |threads: usize| {
        let mut c = cfg(Variant::SyncA2A, lossy_plan(3), Recovery::default());
        c.compute_threads = threads;
        run_federated(&p, &c, policy, false)
    };
    let base = run(1);
    assert!(base.converged, "stop={:?}", base.stop);
    assert!(base.traffic.drops > 0 && base.traffic.retransmits > 0, "plan never fired");
    for t in thread_counts() {
        let reps = if t == 1 { 2 } else { 1 };
        for rep in 0..reps {
            let out = run(t);
            assert_eq!(out.iterations, base.iterations, "{t} threads rep {rep}");
            let what = format!("faulted sync u at {t} threads rep {rep}");
            assert_bit_identical(out.state.u.as_slice(), base.state.u.as_slice(), &what);
            assert_bit_identical(out.state.v.as_slice(), base.state.v.as_slice(), &what);
        }
    }
}

#[test]
fn sync_recovery_is_exact_under_drop_dup_reorder() {
    // Acceptance pin: with drop/dup/reorder faults (no crash) at the
    // F64 wire, both lock-step coordinators reproduce the lossless
    // baseline bit for bit with the same iteration counts — the ARQ
    // layer repriced the run but never touched a payload.
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-11, max_iters: 1500, ..Default::default() };
    for variant in [Variant::SyncA2A, Variant::SyncStar] {
        let lossless = cfg(variant, FaultPlan::none(), Recovery::default());
        let lossy = cfg(variant, lossy_plan(21), Recovery::default());
        let clean = run_federated(&p, &lossless, policy, false);
        let faulted = run_federated(&p, &lossy, policy, false);
        let name = variant.name();
        assert!(clean.converged, "{name} lossless: stop={:?}", clean.stop);
        assert_eq!(faulted.stop, clean.stop, "{name}");
        assert_eq!(faulted.iterations, clean.iterations, "{name}");
        assert_bit_identical(
            faulted.state.u.as_slice(),
            clean.state.u.as_slice(),
            &format!("{name} u under faults"),
        );
        assert_bit_identical(
            faulted.state.v.as_slice(),
            clean.state.v.as_slice(),
            &format!("{name} v under faults"),
        );
        assert!(!faulted.degraded && faulted.lost_nodes.is_empty(), "{name}: no crash injected");
        assert_eq!(clean.traffic.drops + clean.traffic.retransmits, 0, "{name} lossless");
        assert!(
            faulted.traffic.drops > 0 && faulted.traffic.retransmits > 0,
            "{name}: counters must show the plan fired (drops={}, retransmits={})",
            faulted.traffic.drops,
            faulted.traffic.retransmits
        );
    }
}

#[test]
fn async_protocols_converge_through_a_lossy_fabric() {
    // Latest-wins streams genuinely lose dropped frames, so the async
    // pin is convergence-to-threshold with live fault counters, not bit
    // equality (those protocols are timing-nondeterministic even on a
    // clean fabric).
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-8, max_iters: 4000, ..Default::default() };
    for variant in [Variant::AsyncA2A, Variant::AsyncStar] {
        let lossy = cfg(variant, lossy_plan(5), Recovery::default());
        let out = run_federated(&p, &lossy, policy, false);
        let name = variant.name();
        assert!(out.converged, "{name}: stop={:?} after {} iters", out.stop, out.iterations);
        assert!(out.traffic.drops > 0, "{name}: plan never fired");
        assert!(!out.degraded && out.lost_nodes.is_empty(), "{name}: no crash injected");
    }
}

#[test]
fn sync_a2a_abort_flags_peer_loss_without_hanging() {
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-11, max_iters: 300, ..Default::default() };
    let c = cfg(Variant::SyncA2A, crash_plan(1, 3), fast_recovery(NodeLoss::Abort));
    let out = run_with_timeout("sync-a2a abort", move || run_federated(&p, &c, policy, false));
    assert_eq!(out.stop, StopReason::PeerLoss);
    assert!(out.degraded && out.lost_nodes.contains(&1), "lost={:?}", out.lost_nodes);
    assert!(!out.converged);
}

#[test]
fn sync_a2a_exclude_continues_degraded() {
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-11, max_iters: 60, ..Default::default() };
    let c = cfg(Variant::SyncA2A, crash_plan(1, 3), fast_recovery(NodeLoss::Exclude));
    let out = run_with_timeout("sync-a2a exclude", move || {
        run_federated(&p, &c, policy, false)
    });
    // The survivor runs the protocol to completion against node 1's
    // frozen slice — degraded and flagged, but never aborted.
    assert_ne!(out.stop, StopReason::PeerLoss, "exclude must not abort");
    assert!(out.degraded && out.lost_nodes.contains(&1), "lost={:?}", out.lost_nodes);
}

#[test]
fn sync_star_server_crash_aborts_clients() {
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-11, max_iters: 300, ..Default::default() };
    // Node id 2 is the server of a 2-client star; losing it is always
    // fatal to the clients — it owns the kernel — even under `exclude`.
    let c = cfg(Variant::SyncStar, crash_plan(2, 3), fast_recovery(NodeLoss::Exclude));
    let out = run_with_timeout("sync-star server crash", move || {
        run_federated(&p, &c, policy, false)
    });
    assert_eq!(out.stop, StopReason::PeerLoss);
    assert!(out.degraded && out.lost_nodes.contains(&2), "lost={:?}", out.lost_nodes);
}

#[test]
fn sync_star_client_crash_excludes_and_finishes() {
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-11, max_iters: 60, ..Default::default() };
    let c = cfg(Variant::SyncStar, crash_plan(0, 3), fast_recovery(NodeLoss::Exclude));
    let out = run_with_timeout("sync-star client crash", move || {
        run_federated(&p, &c, policy, false)
    });
    assert_ne!(out.stop, StopReason::PeerLoss, "exclude must not abort");
    assert!(out.degraded && out.lost_nodes.contains(&0), "lost={:?}", out.lost_nodes);
}

#[test]
fn async_a2a_crash_degrades_gracefully() {
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-8, max_iters: 600, ..Default::default() };
    let c = cfg(Variant::AsyncA2A, crash_plan(1, 5), fast_recovery(NodeLoss::Exclude));
    let out = run_with_timeout("async-a2a crash", move || run_federated(&p, &c, policy, false));
    // The survivor folds the dead peer into its done votes and finishes
    // on its own slice; the outcome is flagged, never a hang.
    assert!(out.degraded && out.lost_nodes.contains(&1), "lost={:?}", out.lost_nodes);
}

#[test]
fn async_star_client_crash_degrades_gracefully() {
    let p = ProblemSpec::new(32).with_eps(0.5).build(0xFA17);
    let policy = StopPolicy { threshold: 1e-8, max_iters: 600, ..Default::default() };
    let c = cfg(Variant::AsyncStar, crash_plan(1, 5), fast_recovery(NodeLoss::Exclude));
    let out = run_with_timeout("async-star client crash", move || {
        run_federated(&p, &c, policy, false)
    });
    assert!(out.degraded && out.lost_nodes.contains(&1), "lost={:?}", out.lost_nodes);
}
