//! Multi-tenant service acceptance pins.
//!
//! Three contracts from the serving layer, on a ≥64-request workload
//! over one shared ill-conditioned geometry:
//!
//! 1. **Tolerance** — every request converges, and the a-marginal L1
//!    error recomputed from its *frozen* scaling pair (dense log-domain
//!    oracle, independent of the solver's absorbed kernels) honors the
//!    request's own tolerance.
//! 2. **Parity** — batching is invisible to the answer: the Sinkhorn
//!    iteration is column-separable, so a batched column's marginals
//!    match a standalone single-histogram solve to ≤ 1e-8.
//! 3. **Amortization** — one shared absorbed support per batch means
//!    the batched run's total full retruncations stay *strictly* below
//!    the sum over standalone runs.
//!
//! Plus the per-column stopping pin: jittered tolerances must freeze
//! different columns at different iterations.

use fedsink::config::BackendKind;
use fedsink::experiments::build_problem;
use fedsink::linalg::{Domain, Mat};
use fedsink::runtime::make_backend;
use fedsink::service::{run_service, synth_requests, ServiceConfig, WorkloadSpec};
use fedsink::sinkhorn::{CentralizedSolver, StopPolicy};
use fedsink::testkit::run_with_timeout;
use fedsink::workload::{CondClass, Problem};

const N: usize = 48;
const EPS: f64 = 0.005;
const MAX_ITERS: usize = 20_000;

/// Dense log-domain oracle for the a-marginal of one column:
/// `exp(u_i + logsumexp_j(log K_ij + v_j))`. Deliberately bypasses the
/// truncated/absorbed kernels the solver iterated on.
fn a_marginal(p: &Problem, u: &[f64], v: &[f64]) -> Vec<f64> {
    let lk = p.log_kernel();
    (0..p.n)
        .map(|i| {
            let row = lk.row(i);
            let mut mx = f64::NEG_INFINITY;
            for j in 0..p.n {
                mx = mx.max(row[j] + v[j]);
            }
            if mx == f64::NEG_INFINITY {
                return 0.0;
            }
            let s: f64 = (0..p.n).map(|j| (row[j] + v[j] - mx).exp()).sum();
            (u[i] + mx + s.ln()).exp()
        })
        .collect()
}

fn l1(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

#[test]
fn batched_service_matches_standalone_within_tolerance_and_amortizes_rebuilds() {
    let geometry = build_problem(N, 1, EPS, 0.0, 2, CondClass::Ill, 0x5E21);
    let wl = WorkloadSpec {
        requests: 64,
        tenants: 8,
        perturb: 1.0,
        arrival_rate: 0.0, // one burst: batches fill to max_batch
        threshold: 1e-9,
        tolerance_jitter: 1.0,
        seed: 0xBEE5,
    };
    let mut requests = synth_requests(N, &wl);
    for r in &mut requests {
        r.eps = EPS;
    }
    let cfg = ServiceConfig {
        max_iters: MAX_ITERS,
        max_batch: 16,
        domain: Domain::Log,
        ..Default::default()
    };
    let backend = make_backend(BackendKind::Native, "", 1).unwrap();

    let rep = {
        let (backend, geometry, requests, cfg) =
            (backend.clone(), geometry.clone(), requests.clone(), cfg.clone());
        run_with_timeout("batched service run", move || {
            run_service(backend, &geometry, &requests, &cfg)
        })
    };
    assert_eq!(rep.requests.len(), 64);
    assert_eq!(rep.unconverged(), 0, "every request must converge");
    // Burst + max_batch 16 + modest perturbation: full batches.
    let sizes: Vec<usize> = rep.batches.iter().map(|b| b.size).collect();
    assert_eq!(rep.batches.len(), 4, "sizes {sizes:?}");

    // Per-column stopping actually fired: jittered tolerances freeze
    // different columns at different iterations.
    assert!(rep.early_frozen() > 0, "no column froze before its batch finished");
    let mut iter_spread = false;
    for b in 0..rep.batches.len() {
        let iters: Vec<usize> = rep
            .requests
            .iter()
            .filter(|r| r.batch == b)
            .map(|r| r.iterations)
            .collect();
        iter_spread |= iters.iter().any(|&k| k != iters[0]);
    }
    assert!(iter_spread, "all columns froze in lock-step — jitter had no effect");

    // Standalone baseline: every request solved alone at its own
    // tolerance, capturing both the scalings (for parity) and the
    // hybrid counters (for the amortization pin).
    let solver = CentralizedSolver::new(backend);
    let mut standalone_rebuilds = 0usize;
    let mut frozen_by_id: Vec<(Vec<f64>, Vec<f64>)> = Vec::with_capacity(64);
    for req in &requests {
        let mut b1 = Mat::zeros(N, 1);
        for i in 0..N {
            b1[(i, 0)] = req.b[i];
        }
        let mut p1 = Problem::from_parts(geometry.a.clone(), b1, geometry.cost.clone(), EPS);
        p1.masked_cost_min = geometry.masked_cost_min;
        let out = solver.solve_in(
            &p1,
            StopPolicy { threshold: req.threshold, max_iters: MAX_ITERS, ..Default::default() },
            cfg.alpha,
            Domain::Log,
        );
        assert!(out.converged(), "standalone request {} stalled: {:?}", req.id, out.stop);
        standalone_rebuilds += out.stab.as_ref().map(|s| s.rebuilds).unwrap_or(0);
        let u: Vec<f64> = (0..N).map(|i| out.state.u[(i, 0)]).collect();
        let v: Vec<f64> = (0..N).map(|i| out.state.v[(i, 0)]).collect();
        frozen_by_id.push((u, v));
    }

    // Tolerance + parity, per request.
    for req in &requests {
        let got = &rep.requests[req.id as usize];
        assert_eq!(got.id, req.id);
        assert!(got.converged);
        // The frozen pair honors the request tolerance against the
        // dense oracle (small slack for the oracle-vs-absorbed
        // round-off at the freeze check).
        let ma = a_marginal(&geometry, &got.u, &got.v);
        let err = l1(&ma, &geometry.a);
        assert!(
            err <= req.threshold + 1e-11,
            "request {}: recomputed err {err:.3e} vs tolerance {:.3e}",
            req.id,
            req.threshold
        );
        // Parity with the standalone solve: same iterate sequence by
        // column separability, so the marginals agree to ≤ 1e-8.
        let (su, sv) = &frozen_by_id[req.id as usize];
        let sa = a_marginal(&geometry, su, sv);
        let gap = l1(&ma, &sa);
        assert!(gap <= 1e-8, "request {}: batched vs standalone marginal gap {gap:.3e}", req.id);
    }

    // Amortization: one shared support per batch beats per-request
    // supports — strictly, and the baseline actually retruncated (else
    // the pin is vacuous).
    let batched_rebuilds = rep.rebuilds();
    assert!(standalone_rebuilds > 0, "baseline never rebuilt — workload too easy to pin");
    assert!(
        batched_rebuilds < standalone_rebuilds,
        "batched rebuilds {batched_rebuilds} not strictly below standalone sum {standalone_rebuilds}"
    );
}
