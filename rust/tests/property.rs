//! Randomized property tests over coordinator/runtime/substrate
//! invariants. (`proptest` does not resolve in this offline image, so
//! the sweeps run on the crate's own seeded PRNG — shrinkage is traded
//! for reproducible failure seeds, printed on assert.)

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::jsonio::{parse, to_string_pretty, Json};
use fedsink::linalg::{logsumexp_slice, Domain, LogCsr, Mat, Stabilization};
use fedsink::net::LatencyModel;
use fedsink::rng::{child_seed, Rng};
use fedsink::runtime::{make_backend, ComputeBackend, NativeBackend, Target};
use fedsink::sinkhorn::{full_marginal_errors, CentralizedSolver, StopPolicy};
use fedsink::workload::{CondClass, Partition, Problem, ProblemSpec};

const SWEEPS: usize = 25;

fn policy() -> StopPolicy {
    StopPolicy { threshold: 1e-11, max_iters: 4000, ..Default::default() }
}

/// Prop. 1 as a property: for random problems and random client counts,
/// both synchronous variants reproduce the centralized fixed point.
#[test]
fn prop_sync_variants_match_centralized() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xF00D, case as u64));
        let c = [1usize, 2, 3, 4][rng.below(4)];
        let n = c * (2 + rng.below(8)); // n divisible by c, up to 36
        let nh = 1 + rng.below(3);
        let eps = rng.uniform_range(0.2, 0.8);
        let p = ProblemSpec::new(n).with_hists(nh).with_eps(eps).build(case as u64);
        let central = CentralizedSolver::new(native.clone()).solve(&p, policy(), 1.0);
        if !central.converged() {
            continue; // ill-conditioned draw; convergence tested elsewhere
        }
        for variant in [Variant::SyncA2A, Variant::SyncStar] {
            let cfg = SolveConfig {
                variant,
                backend: BackendKind::Native,
                clients: c,
                net: LatencyModel::zero(),
                ..Default::default()
            };
            let out = run_federated(&p, &cfg, policy(), false);
            assert!(out.converged, "case {case}: {} c={c} n={n}", variant.name());
            assert!(
                out.state.u.allclose(&central.state.u, 1e-8),
                "case {case}: {} diverges from centralized (c={c}, n={n}, nh={nh})",
                variant.name()
            );
        }
    }
}

/// Damped async runs must either converge to a valid plan or report
/// non-convergence — never return a "converged" state violating the
/// marginals.
#[test]
fn prop_async_converged_implies_valid_plan() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xBEEF, case as u64));
        let c = [2usize, 3, 4][rng.below(3)];
        let n = c * (3 + rng.below(6));
        let p = ProblemSpec::new(n).with_eps(rng.uniform_range(0.3, 0.8)).build(70 + case as u64);
        let variant = if case % 2 == 0 { Variant::AsyncA2A } else { Variant::AsyncStar };
        let cfg = SolveConfig {
            variant,
            backend: BackendKind::Native,
            clients: c,
            alpha: rng.uniform_range(0.3, 0.7),
            net: LatencyModel::zero(),
            seed: case as u64,
            ..Default::default()
        };
        let out = run_federated(&p, &cfg, policy(), false);
        if out.converged {
            let (ea, eb) = full_marginal_errors(&p, &out.state, 0);
            assert!(
                ea < 1e-5 && eb < 1e-5,
                "case {case}: {} claimed convergence with errors ({ea:.2e}, {eb:.2e})",
                variant.name()
            );
        }
    }
}

/// Partition slicing is lossless: shards reassemble the kernel exactly.
#[test]
fn prop_partition_reassembles() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xCAFE, case as u64));
        let c = 1 + rng.below(6);
        let m = 1 + rng.below(7);
        let n = c * m;
        let p = ProblemSpec::new(n).with_hists(1 + rng.below(2)).build(case as u64);
        let part = Partition::new(&p, c);
        for sh in &part.shards {
            for i in 0..sh.m() {
                assert_eq!(sh.a[i], p.a[sh.r0 + i]);
                for j in 0..n {
                    assert_eq!(sh.k_row[(i, j)], p.kernel()[(sh.r0 + i, j)]);
                    assert_eq!(sh.k_col_t[(i, j)], p.kernel()[(j, sh.r0 + i)]);
                }
            }
        }
    }
}

/// BlockOp state algebra: update(α=0) is the identity; update(α=1)
/// matches the raw Sinkhorn formula; interleavings stay consistent.
#[test]
fn prop_blockop_damping_algebra() {
    let be = NativeBackend::new(1);
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xDEAD, case as u64));
        let m = 1 + rng.below(9);
        let n = 1 + rng.below(9);
        let nh = 1 + rng.below(3);
        let a = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
        let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let u0 = Mat::rand_uniform(m, nh, 0.1, 1.0, &mut rng);
        let mut op = be.block_op(&a, Target::Vec(&t), u0.clone()).unwrap();
        let frozen = op.update(&x, 0.0).clone();
        assert!(frozen.allclose(&u0, 0.0), "case {case}: α=0 changed state");
        let sharp = op.update(&x, 1.0).clone();
        let q = a.matmul(&x, 1);
        for i in 0..m {
            for h in 0..nh {
                let want = t[i] / q[(i, h)];
                assert!(
                    (sharp[(i, h)] - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "case {case}: α=1 mismatch"
                );
            }
        }
    }
}

/// JSON writer/parser round-trip over random documents.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.uniform_range(-1e6, 1e6) * 1e3).round() / 1e3),
            3 => {
                let len = rng.below(12);
                Json::Str((0..len).map(|_| ('a'..='z').nth(rng.below(26)).unwrap()).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..200 {
        let mut rng = Rng::seed_from(child_seed(0x15EA5E, case as u64));
        let doc = random_json(&mut rng, 3);
        let text = to_string_pretty(&doc);
        let back = parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, doc, "case {case}");
    }
}

/// The blocked/threaded logsumexp kernel pinned against the naive
/// `ln(Σ exp)` formula on ranges where the naive form cannot underflow,
/// for random shapes, scalings and thread counts.
#[test]
fn prop_logsumexp_matches_naive() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0x15E, case as u64));
        let m = 1 + rng.below(24);
        let n = 1 + rng.below(48);
        let nh = 1 + rng.below(4);
        let threads = 1 + rng.below(4);
        let a = Mat::rand_uniform(m, n, -6.0, 2.0, &mut rng);
        let x = Mat::rand_uniform(n, nh, -3.0, 3.0, &mut rng);
        let got = a.logsumexp(&x, threads);
        for i in 0..m {
            for h in 0..nh {
                let naive: f64 =
                    (0..n).map(|k| (a[(i, k)] + x[(k, h)]).exp()).sum::<f64>().ln();
                // Also cross-check the shared slice helper.
                let terms: Vec<f64> = (0..n).map(|k| a[(i, k)] + x[(k, h)]).collect();
                let stable = logsumexp_slice(&terms);
                assert!(
                    (got[(i, h)] - naive).abs() <= 1e-11 * naive.abs().max(1.0),
                    "case {case} ({m},{n},{nh}) t={threads} at ({i},{h}): {} vs naive {naive}",
                    got[(i, h)]
                );
                assert!((got[(i, h)] - stable).abs() <= 1e-11 * stable.abs().max(1.0));
            }
        }
    }
}

/// Log-domain and linear-domain centralized solves agree to 1e-9 on
/// random moderate-ε problems (multi-histogram included) — the
/// representations are exchangeable wherever both are well-posed.
#[test]
fn prop_log_and_linear_solves_agree() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    let solver = CentralizedSolver::new(native);
    for case in 0..10 {
        let mut rng = Rng::seed_from(child_seed(0x10C, case as u64));
        let n = 8 + rng.below(17);
        let nh = 1 + rng.below(3);
        let eps = rng.uniform_range(0.2, 0.8);
        let p = ProblemSpec::new(n).with_hists(nh).with_eps(eps).build(500 + case as u64);
        let lin = solver.solve_in(&p, policy(), 1.0, Domain::Linear);
        let log = solver.solve_in(&p, policy(), 1.0, Domain::Log);
        if !lin.converged() {
            continue; // ill-conditioned draw; convergence tested elsewhere
        }
        assert!(log.converged(), "case {case}: log solve stalled (n={n}, eps={eps:.3})");
        // Identical sequences in exact arithmetic; fp rounding may shift
        // the stopping check by at most one cadence step.
        assert!(
            lin.iterations.abs_diff(log.iterations) <= 1,
            "case {case}: iterate counts diverged ({} vs {})",
            lin.iterations,
            log.iterations
        );
        for h in 0..nh {
            for i in 0..n {
                let want = lin.state.u[(i, h)];
                let got = log.state.u[(i, h)].exp();
                assert!(
                    (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                    "case {case}: u[{i},{h}] {got} vs {want} (n={n}, eps={eps:.3})"
                );
            }
        }
    }
}

/// Sparse-log LSE ≡ dense-log LSE on randomly masked kernels, including
/// fully masked rows (which must logsumexp to −∞, not NaN), across
/// random shapes, histogram counts and thread counts.
#[test]
fn prop_sparse_log_lse_matches_dense() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0x10CC, case as u64));
        let m = 1 + rng.below(24);
        let n = 1 + rng.below(40);
        let nh = 1 + rng.below(3);
        let threads = 1 + rng.below(4);
        let mut a = Mat::rand_uniform(m, n, -6.0, 2.0, &mut rng);
        for i in 0..m {
            for j in 0..n {
                if rng.uniform() < 0.6 {
                    a[(i, j)] = f64::NEG_INFINITY;
                }
            }
        }
        // Force at least one fully masked row when there is room.
        if m > 1 {
            let full = rng.below(m);
            for j in 0..n {
                a[(full, j)] = f64::NEG_INFINITY;
            }
        }
        let lc = LogCsr::from_dense_log(&a, f64::NEG_INFINITY);
        let x = Mat::rand_uniform(n, nh, -3.0, 3.0, &mut rng);
        let want = a.logsumexp(&x, threads);
        let got = lc.logsumexp(&x, threads);
        for i in 0..m {
            for h in 0..nh {
                let (w, g) = (want[(i, h)], got[(i, h)]);
                if w == f64::NEG_INFINITY {
                    assert_eq!(g, w, "case {case} ({i},{h}): masked row must stay −∞");
                } else {
                    assert!(
                        (w - g).abs() <= 1e-12 * w.abs().max(1.0),
                        "case {case} ({m},{n},{nh}) t={threads} at ({i},{h}): {g} vs {w}"
                    );
                }
            }
        }
    }
}

/// A fixed-cost (ε-independent) problem: uniform costs in [0, 1], so
/// `max C/ε` genuinely grows as ε shrinks. (`ProblemSpec` scales its
/// cost spread *with* ε by design, which keeps conditioning ε-invariant
/// — useless for exercising the small-ε stabilized path.)
fn fixed_cost_problem_hists(n: usize, nh: usize, eps: f64, seed: u64) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let a = rng.dirichlet(n, 1.0);
    let mut b = Mat::zeros(n, nh);
    for h in 0..nh {
        let bcol = rng.dirichlet(n, 1.0);
        for i in 0..n {
            b[(i, h)] = bcol[i];
        }
    }
    let mut cost = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            if i != j {
                cost[(i, j)] = rng.uniform();
            }
        }
    }
    Problem::from_parts(a, b, cost, eps)
}

fn fixed_cost_problem(n: usize, eps: f64, seed: u64) -> Problem {
    fixed_cost_problem_hists(n, 1, eps, seed)
}

/// Absorption-hybrid iterates ≡ pure log-domain iterates: both schedules
/// run exactly 60 undamped iterations at ε ∈ {0.05, 0.01, 0.005} on a
/// fixed-cost problem (max C/ε up to 200) and must land on the same
/// log-scalings to 1e-10 — the hybrid's GEMV-on-absorbed-kernel products
/// and re-absorptions are pure refactorings of the logsumexp.
#[test]
fn prop_hybrid_iterates_match_pure_log() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    let pure =
        CentralizedSolver::new(native.clone()).with_stabilization(Stabilization::disabled());
    let hybrid = CentralizedSolver::new(native);
    for (case, &eps) in [0.05f64, 0.01, 0.005].iter().enumerate() {
        let p = fixed_cost_problem(32, eps, child_seed(0xAB50, case as u64));
        // threshold 0 ⇒ never converges: both runs perform exactly
        // max_iters iterations (check cadence kept sparse).
        let pol =
            StopPolicy { threshold: 0.0, max_iters: 60, check_every: 50, ..Default::default() };
        let o_pure = pure.solve_in(&p, pol, 1.0, Domain::Log);
        let o_hyb = hybrid.solve_in(&p, pol, 1.0, Domain::Log);
        assert_eq!(o_pure.iterations, 60);
        assert_eq!(o_hyb.iterations, 60);
        assert!(o_pure.stab.is_none(), "disabled stabilization must stay dense");
        let stats = o_hyb.stab.expect("hybrid must report stats");
        assert!(stats.updates == 120, "two ops × 60 iterations, got {}", stats.updates);
        for i in 0..p.n {
            let (du, hu) = (o_pure.state.u[(i, 0)], o_hyb.state.u[(i, 0)]);
            assert!(
                (du - hu).abs() < 1e-10,
                "eps {eps} u[{i}]: hybrid {hu} vs pure {du}"
            );
            let (dv, hv) = (o_pure.state.v[(i, 0)], o_hyb.state.v[(i, 0)]);
            assert!(
                (dv - hv).abs() < 1e-10,
                "eps {eps} v[{i}]: hybrid {hv} vs pure {dv}"
            );
        }
    }
}

/// The acceptance bar for the hybrid engine: an ε = 0.005 solve (max
/// C/ε = 200 — far into the regime where the linear kernel loses
/// precision) converges, matches the pure log-domain solution's marginal
/// errors within 1e-8, and spends ≥ 80% of its iterations on the linear
/// GEMV path (re-absorptions are rare once the duals settle).
#[test]
fn hybrid_small_eps_solve_is_mostly_linear_and_accurate() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    let pure =
        CentralizedSolver::new(native.clone()).with_stabilization(Stabilization::disabled());
    let hybrid = CentralizedSolver::new(native);
    let p = fixed_cost_problem(48, 0.005, 0xFEED5);
    let pol = StopPolicy {
        threshold: 1e-10,
        max_iters: 200_000,
        check_every: 10,
        ..Default::default()
    };
    let o_pure = pure.solve_in(&p, pol, 1.0, Domain::Log);
    let o_hyb = hybrid.solve_in(&p, pol, 1.0, Domain::Log);
    assert!(o_pure.converged(), "pure log solve: {:?}", o_pure.stop);
    assert!(o_hyb.converged(), "hybrid solve: {:?}", o_hyb.stop);
    let (ea_p, eb_p) = full_marginal_errors(&p, &o_pure.state, 0);
    let (ea_h, eb_h) = full_marginal_errors(&p, &o_hyb.state, 0);
    assert!(
        (ea_p - ea_h).abs() < 1e-8 && (eb_p - eb_h).abs() < 1e-8,
        "marginal errors diverged: pure ({ea_p:.3e}, {eb_p:.3e}) hybrid ({ea_h:.3e}, {eb_h:.3e})"
    );
    let stats = o_hyb.stab.expect("hybrid stats");
    assert!(stats.updates >= 2 * o_hyb.iterations);
    assert!(
        stats.linear_fraction() >= 0.8,
        "only {:.1}% of iterations stayed on the GEMV path ({} absorbs / {} updates)",
        100.0 * stats.linear_fraction(),
        stats.absorbs,
        stats.updates
    );
}

/// Multi-histogram absorption-hybrid iterates ≡ pure log-domain
/// iterates: for N ∈ {2, 8} and ε ∈ {0.01, 0.005} both schedules run
/// exactly 60 undamped iterations on a fixed-cost problem and must land
/// on the same log-scalings to 1e-10 — per histogram, both against the
/// vectorized pure solve and against N separate single-histogram pure
/// solves (the shared-support batched GEMM is a pure refactoring of N
/// independent logsumexp recursions).
#[test]
fn prop_multihist_hybrid_iterates_match_pure_log() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    let pure =
        CentralizedSolver::new(native.clone()).with_stabilization(Stabilization::disabled());
    let hybrid = CentralizedSolver::new(native);
    let pol =
        StopPolicy { threshold: 0.0, max_iters: 60, check_every: 50, ..Default::default() };
    for &nh in &[2usize, 8] {
        for (case, &eps) in [0.01f64, 0.005].iter().enumerate() {
            let p = fixed_cost_problem_hists(
                32,
                nh,
                eps,
                child_seed(0xAB51, (nh * 10 + case) as u64),
            );
            let o_pure = pure.solve_in(&p, pol, 1.0, Domain::Log);
            let o_hyb = hybrid.solve_in(&p, pol, 1.0, Domain::Log);
            assert_eq!(o_pure.iterations, 60);
            assert_eq!(o_hyb.iterations, 60);
            let stats = o_hyb.stab.clone().expect("hybrid must report stats");
            assert_eq!(stats.updates, 120, "two ops x 60 iterations");
            assert_eq!(stats.absorb_triggers.len(), nh, "per-histogram trigger slots");
            for h in 0..nh {
                for i in 0..p.n {
                    let (du, hu) = (o_pure.state.u[(i, h)], o_hyb.state.u[(i, h)]);
                    assert!(
                        (du - hu).abs() < 1e-10,
                        "N={nh} eps {eps} u[{i},{h}]: hybrid {hu} vs pure {du}"
                    );
                    let (dv, hv) = (o_pure.state.v[(i, h)], o_hyb.state.v[(i, h)]);
                    assert!(
                        (dv - hv).abs() < 1e-10,
                        "N={nh} eps {eps} v[{i},{h}]: hybrid {hv} vs pure {dv}"
                    );
                }
            }
            // Per-histogram cross-check: each column of the vectorized
            // hybrid matches a standalone single-histogram pure solve.
            for h in 0..nh {
                let mut bh = Mat::zeros(p.n, 1);
                for i in 0..p.n {
                    bh[(i, 0)] = p.b[(i, h)];
                }
                let single = Problem::from_parts(p.a.clone(), bh, p.cost.clone(), p.eps);
                let o_single = pure.solve_in(&single, pol, 1.0, Domain::Log);
                for i in 0..p.n {
                    assert!(
                        (o_single.state.u[(i, 0)] - o_hyb.state.u[(i, h)]).abs() < 1e-10,
                        "N={nh} eps {eps} hist {h} vs standalone solve, row {i}"
                    );
                }
            }
        }
    }
}

/// The acceptance bar for the vectorized hybrid engine: an N = 8,
/// ε = 0.005 solve (max C/ε = 200) converges, matches the pure
/// log-domain solution's marginal errors within 1e-8 on every
/// histogram, and spends ≥ 70% of its iterations on the batched linear
/// GEMM path. (CI drives the n = 512 version of this bar through the
/// `solve --hists 8` smoke step.)
#[test]
fn multihist_small_eps_solve_is_mostly_linear_and_accurate() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    let pure =
        CentralizedSolver::new(native.clone()).with_stabilization(Stabilization::disabled());
    let hybrid = CentralizedSolver::new(native);
    let p = fixed_cost_problem_hists(64, 8, 0.005, 0xFEED6);
    let pol = StopPolicy {
        threshold: 1e-10,
        max_iters: 200_000,
        check_every: 10,
        ..Default::default()
    };
    let o_pure = pure.solve_in(&p, pol, 1.0, Domain::Log);
    let o_hyb = hybrid.solve_in(&p, pol, 1.0, Domain::Log);
    assert!(o_pure.converged(), "pure log solve: {:?}", o_pure.stop);
    assert!(o_hyb.converged(), "hybrid solve: {:?}", o_hyb.stop);
    for h in 0..8 {
        let (ea_p, eb_p) = full_marginal_errors(&p, &o_pure.state, h);
        let (ea_h, eb_h) = full_marginal_errors(&p, &o_hyb.state, h);
        assert!(
            (ea_p - ea_h).abs() < 1e-8 && (eb_p - eb_h).abs() < 1e-8,
            "hist {h} marginal errors diverged: pure ({ea_p:.3e}, {eb_p:.3e}) \
             hybrid ({ea_h:.3e}, {eb_h:.3e})"
        );
    }
    let stats = o_hyb.stab.expect("hybrid stats");
    assert!(stats.updates >= 2 * o_hyb.iterations);
    assert!(
        stats.linear_fraction() >= 0.7,
        "only {:.1}% of iterations stayed on the batched GEMM path \
         ({} absorbs / {} updates)",
        100.0 * stats.linear_fraction(),
        stats.absorbs,
        stats.updates
    );
    assert_eq!(stats.absorb_triggers.len(), 8);
    assert!(
        stats.absorb_triggers.iter().sum::<usize>() >= stats.absorbs,
        "every absorb must record its triggering histogram(s)"
    );
}

/// Forced per-histogram re-absorption: a tiny τ makes single histograms
/// trip the drift bound constantly; the schedule must stay a pure
/// refactoring of the logsumexp recursion (iterates within 1e-10 of the
/// dense path) while re-absorbing nearly every iteration.
#[test]
fn multihist_hybrid_survives_forced_reabsorption() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    let pure =
        CentralizedSolver::new(native.clone()).with_stabilization(Stabilization::disabled());
    let tight = Stabilization { absorb_threshold: 0.05, ..Stabilization::default() };
    let hybrid = CentralizedSolver::new(native).with_stabilization(tight);
    let p = fixed_cost_problem_hists(24, 4, 0.01, 0xF0CE);
    let pol =
        StopPolicy { threshold: 0.0, max_iters: 40, check_every: 50, ..Default::default() };
    let o_pure = pure.solve_in(&p, pol, 1.0, Domain::Log);
    let o_hyb = hybrid.solve_in(&p, pol, 1.0, Domain::Log);
    let stats = o_hyb.stab.clone().expect("hybrid stats");
    assert!(
        stats.absorbs > o_hyb.iterations,
        "tau = 0.05 must force re-absorption on most updates ({} absorbs / {} iters)",
        stats.absorbs,
        o_hyb.iterations
    );
    assert!(stats.rebuilds >= 1, "large early dual moves must re-truncate");
    assert!(
        stats.absorb_triggers.iter().all(|&t| t > 0),
        "every histogram must trip the tiny drift bound: {:?}",
        stats.absorb_triggers
    );
    for h in 0..4 {
        for i in 0..p.n {
            assert!(
                (o_pure.state.u[(i, h)] - o_hyb.state.u[(i, h)]).abs() < 1e-10,
                "u[{i},{h}] diverged under forced re-absorption"
            );
        }
    }
}

/// Sparsity monotonicity: higher s never produces a denser kernel.
#[test]
fn prop_sparsity_monotone() {
    for case in 0..SWEEPS {
        let n = 32;
        let count_tiny = |s: f64| {
            let p = ProblemSpec::new(n).with_sparsity(s, 4).build(case as u64);
            p.kernel().as_slice().iter().filter(|&&x| x < 1e-100).count()
        };
        let z = count_tiny(0.0);
        let h = count_tiny(0.5);
        let f = count_tiny(1.0);
        assert!(z <= h && h <= f, "case {case}: {z} {h} {f}");
    }
}

/// Condition classes give finite positive kernels at every sparsity.
#[test]
fn prop_kernel_entries_finite() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xFEED, case as u64));
        let cond = [CondClass::Well, CondClass::Medium, CondClass::Ill][rng.below(3)];
        let s = [0.0, 0.5, 0.9, 1.0][rng.below(4)];
        let p = ProblemSpec::new(24)
            .with_sparsity(s, 4)
            .with_condition(cond)
            .build(case as u64);
        assert!(p.kernel().as_slice().iter().all(|x| x.is_finite() && *x >= 0.0));
        // Diagonal blocks always survive sparsification.
        assert!(p.kernel()[(0, 0)] > 0.0);
    }
}
