//! Randomized property tests over coordinator/runtime/substrate
//! invariants. (`proptest` does not resolve in this offline image, so
//! the sweeps run on the crate's own seeded PRNG — shrinkage is traded
//! for reproducible failure seeds, printed on assert.)

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::jsonio::{parse, to_string_pretty, Json};
use fedsink::linalg::{logsumexp_slice, Domain, Mat};
use fedsink::net::LatencyModel;
use fedsink::rng::{child_seed, Rng};
use fedsink::runtime::{make_backend, ComputeBackend, NativeBackend, Target};
use fedsink::sinkhorn::{full_marginal_errors, CentralizedSolver, StopPolicy};
use fedsink::workload::{CondClass, Partition, ProblemSpec};

const SWEEPS: usize = 25;

fn policy() -> StopPolicy {
    StopPolicy { threshold: 1e-11, max_iters: 4000, ..Default::default() }
}

/// Prop. 1 as a property: for random problems and random client counts,
/// both synchronous variants reproduce the centralized fixed point.
#[test]
fn prop_sync_variants_match_centralized() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xF00D, case as u64));
        let c = [1usize, 2, 3, 4][rng.below(4)];
        let n = c * (2 + rng.below(8)); // n divisible by c, up to 36
        let nh = 1 + rng.below(3);
        let eps = rng.uniform_range(0.2, 0.8);
        let p = ProblemSpec::new(n).with_hists(nh).with_eps(eps).build(case as u64);
        let central = CentralizedSolver::new(native.clone()).solve(&p, policy(), 1.0);
        if !central.converged() {
            continue; // ill-conditioned draw; convergence tested elsewhere
        }
        for variant in [Variant::SyncA2A, Variant::SyncStar] {
            let cfg = SolveConfig {
                variant,
                backend: BackendKind::Native,
                clients: c,
                net: LatencyModel::zero(),
                ..Default::default()
            };
            let out = run_federated(&p, &cfg, policy(), false);
            assert!(out.converged, "case {case}: {} c={c} n={n}", variant.name());
            assert!(
                out.state.u.allclose(&central.state.u, 1e-8),
                "case {case}: {} diverges from centralized (c={c}, n={n}, nh={nh})",
                variant.name()
            );
        }
    }
}

/// Damped async runs must either converge to a valid plan or report
/// non-convergence — never return a "converged" state violating the
/// marginals.
#[test]
fn prop_async_converged_implies_valid_plan() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xBEEF, case as u64));
        let c = [2usize, 3, 4][rng.below(3)];
        let n = c * (3 + rng.below(6));
        let p = ProblemSpec::new(n).with_eps(rng.uniform_range(0.3, 0.8)).build(70 + case as u64);
        let variant = if case % 2 == 0 { Variant::AsyncA2A } else { Variant::AsyncStar };
        let cfg = SolveConfig {
            variant,
            backend: BackendKind::Native,
            clients: c,
            alpha: rng.uniform_range(0.3, 0.7),
            net: LatencyModel::zero(),
            seed: case as u64,
            ..Default::default()
        };
        let out = run_federated(&p, &cfg, policy(), false);
        if out.converged {
            let (ea, eb) = full_marginal_errors(&p, &out.state, 0);
            assert!(
                ea < 1e-5 && eb < 1e-5,
                "case {case}: {} claimed convergence with errors ({ea:.2e}, {eb:.2e})",
                variant.name()
            );
        }
    }
}

/// Partition slicing is lossless: shards reassemble the kernel exactly.
#[test]
fn prop_partition_reassembles() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xCAFE, case as u64));
        let c = 1 + rng.below(6);
        let m = 1 + rng.below(7);
        let n = c * m;
        let p = ProblemSpec::new(n).with_hists(1 + rng.below(2)).build(case as u64);
        let part = Partition::new(&p, c);
        for sh in &part.shards {
            for i in 0..sh.m() {
                assert_eq!(sh.a[i], p.a[sh.r0 + i]);
                for j in 0..n {
                    assert_eq!(sh.k_row[(i, j)], p.kernel()[(sh.r0 + i, j)]);
                    assert_eq!(sh.k_col_t[(i, j)], p.kernel()[(j, sh.r0 + i)]);
                }
            }
        }
    }
}

/// BlockOp state algebra: update(α=0) is the identity; update(α=1)
/// matches the raw Sinkhorn formula; interleavings stay consistent.
#[test]
fn prop_blockop_damping_algebra() {
    let be = NativeBackend::new(1);
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xDEAD, case as u64));
        let m = 1 + rng.below(9);
        let n = 1 + rng.below(9);
        let nh = 1 + rng.below(3);
        let a = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
        let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let u0 = Mat::rand_uniform(m, nh, 0.1, 1.0, &mut rng);
        let mut op = be.block_op(&a, Target::Vec(&t), u0.clone()).unwrap();
        let frozen = op.update(&x, 0.0).clone();
        assert!(frozen.allclose(&u0, 0.0), "case {case}: α=0 changed state");
        let sharp = op.update(&x, 1.0).clone();
        let q = a.matmul(&x, 1);
        for i in 0..m {
            for h in 0..nh {
                let want = t[i] / q[(i, h)];
                assert!(
                    (sharp[(i, h)] - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "case {case}: α=1 mismatch"
                );
            }
        }
    }
}

/// JSON writer/parser round-trip over random documents.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.uniform_range(-1e6, 1e6) * 1e3).round() / 1e3),
            3 => {
                let len = rng.below(12);
                Json::Str((0..len).map(|_| ('a'..='z').nth(rng.below(26)).unwrap()).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..200 {
        let mut rng = Rng::seed_from(child_seed(0x15EA5E, case as u64));
        let doc = random_json(&mut rng, 3);
        let text = to_string_pretty(&doc);
        let back = parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, doc, "case {case}");
    }
}

/// The blocked/threaded logsumexp kernel pinned against the naive
/// `ln(Σ exp)` formula on ranges where the naive form cannot underflow,
/// for random shapes, scalings and thread counts.
#[test]
fn prop_logsumexp_matches_naive() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0x15E, case as u64));
        let m = 1 + rng.below(24);
        let n = 1 + rng.below(48);
        let nh = 1 + rng.below(4);
        let threads = 1 + rng.below(4);
        let a = Mat::rand_uniform(m, n, -6.0, 2.0, &mut rng);
        let x = Mat::rand_uniform(n, nh, -3.0, 3.0, &mut rng);
        let got = a.logsumexp(&x, threads);
        for i in 0..m {
            for h in 0..nh {
                let naive: f64 =
                    (0..n).map(|k| (a[(i, k)] + x[(k, h)]).exp()).sum::<f64>().ln();
                // Also cross-check the shared slice helper.
                let terms: Vec<f64> = (0..n).map(|k| a[(i, k)] + x[(k, h)]).collect();
                let stable = logsumexp_slice(&terms);
                assert!(
                    (got[(i, h)] - naive).abs() <= 1e-11 * naive.abs().max(1.0),
                    "case {case} ({m},{n},{nh}) t={threads} at ({i},{h}): {} vs naive {naive}",
                    got[(i, h)]
                );
                assert!((got[(i, h)] - stable).abs() <= 1e-11 * stable.abs().max(1.0));
            }
        }
    }
}

/// Log-domain and linear-domain centralized solves agree to 1e-9 on
/// random moderate-ε problems (multi-histogram included) — the
/// representations are exchangeable wherever both are well-posed.
#[test]
fn prop_log_and_linear_solves_agree() {
    let native = make_backend(BackendKind::Native, "", 1).unwrap();
    let solver = CentralizedSolver::new(native);
    for case in 0..10 {
        let mut rng = Rng::seed_from(child_seed(0x10C, case as u64));
        let n = 8 + rng.below(17);
        let nh = 1 + rng.below(3);
        let eps = rng.uniform_range(0.2, 0.8);
        let p = ProblemSpec::new(n).with_hists(nh).with_eps(eps).build(500 + case as u64);
        let lin = solver.solve_in(&p, policy(), 1.0, Domain::Linear);
        let log = solver.solve_in(&p, policy(), 1.0, Domain::Log);
        if !lin.converged() {
            continue; // ill-conditioned draw; convergence tested elsewhere
        }
        assert!(log.converged(), "case {case}: log solve stalled (n={n}, eps={eps:.3})");
        // Identical sequences in exact arithmetic; fp rounding may shift
        // the stopping check by at most one cadence step.
        assert!(
            lin.iterations.abs_diff(log.iterations) <= 1,
            "case {case}: iterate counts diverged ({} vs {})",
            lin.iterations,
            log.iterations
        );
        for h in 0..nh {
            for i in 0..n {
                let want = lin.state.u[(i, h)];
                let got = log.state.u[(i, h)].exp();
                assert!(
                    (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                    "case {case}: u[{i},{h}] {got} vs {want} (n={n}, eps={eps:.3})"
                );
            }
        }
    }
}

/// Sparsity monotonicity: higher s never produces a denser kernel.
#[test]
fn prop_sparsity_monotone() {
    for case in 0..SWEEPS {
        let n = 32;
        let count_tiny = |s: f64| {
            let p = ProblemSpec::new(n).with_sparsity(s, 4).build(case as u64);
            p.kernel().as_slice().iter().filter(|&&x| x < 1e-100).count()
        };
        let z = count_tiny(0.0);
        let h = count_tiny(0.5);
        let f = count_tiny(1.0);
        assert!(z <= h && h <= f, "case {case}: {z} {h} {f}");
    }
}

/// Condition classes give finite positive kernels at every sparsity.
#[test]
fn prop_kernel_entries_finite() {
    for case in 0..SWEEPS {
        let mut rng = Rng::seed_from(child_seed(0xFEED, case as u64));
        let cond = [CondClass::Well, CondClass::Medium, CondClass::Ill][rng.below(3)];
        let s = [0.0, 0.5, 0.9, 1.0][rng.below(4)];
        let p = ProblemSpec::new(24)
            .with_sparsity(s, 4)
            .with_condition(cond)
            .build(case as u64);
        assert!(p.kernel().as_slice().iter().all(|x| x.is_finite() && *x >= 0.0));
        // Diagonal blocks always survive sparsification.
        assert!(p.kernel()[(0, 0)] > 0.0);
    }
}
