//! Pool ≡ scoped-spawn identity pins.
//!
//! The persistent worker pool replaced per-call `crossbeam` scoped
//! spawns in every banded kernel. Its shape contract — the same
//! `div_ceil` row decomposition, every row processed serially inside
//! exactly one band — makes results bit-identical to the old scoped
//! code at every thread count. These tests pin that: each of the four
//! kernel families (dense GEMM, CSR GEMM, log-CSR logsumexp, absorbed
//! log-GEMM) is compared against an inline scoped-spawn reference that
//! computes each band on its own spawned thread, at thread counts
//! {1, 2, available_parallelism}. The streamed folds are pinned
//! against their batch twins at the same counts.

use fedsink::linalg::{AbsorbedLogCsr, Csr, LogCsr, Mat};
use fedsink::rng::{child_seed, Rng};
use fedsink::testkit::run_with_timeout;

/// The pinned thread counts: serial, the smallest parallel split, and
/// the machine's full width (deduplicated on narrow CI runners).
fn thread_counts() -> Vec<usize> {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut ts = vec![1, 2, avail];
    ts.sort_unstable();
    ts.dedup();
    ts
}

/// The exact band decomposition `Pool::run_bands` computes (and the old
/// scoped-spawn call sites computed): at most `threads` contiguous
/// `div_ceil`-sized row bands.
fn bands(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.max(1).min(rows.max(1));
    let per = rows.div_ceil(t);
    (0..rows.div_ceil(per)).map(|b| (b * per, ((b + 1) * per).min(rows))).collect()
}

/// Scoped-spawn reference executor: one freshly spawned thread per
/// band, each computing its `[r0, r1)` rows via `per_band`, assembled
/// into one `rows×nh` flat result — exactly what the retired
/// `crossbeam_utils::thread::scope` kernel sites did.
fn scoped_rows(
    rows: usize,
    nh: usize,
    threads: usize,
    per_band: impl Fn(usize, usize) -> Vec<f64> + Sync,
) -> Vec<f64> {
    let mut out = vec![0.0; rows * nh];
    crossbeam_utils::thread::scope(|s| {
        let handles: Vec<_> = bands(rows, threads)
            .into_iter()
            .map(|(r0, r1)| {
                let f = &per_band;
                s.spawn(move |_| (r0, f(r0, r1)))
            })
            .collect();
        for h in handles {
            let (r0, band) = h.join().expect("band thread");
            out[r0 * nh..r0 * nh + band.len()].copy_from_slice(&band);
        }
    })
    .expect("scope");
    out
}

fn assert_bit_identical(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(g.to_bits() == w.to_bits(), "{what}: index {i} differs: got {g:e}, want {w:e}");
    }
}

/// A ~30% masked dense matrix (linear entries; zeros for the CSR view).
fn sparse_dense(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    let mut m = Mat::rand_uniform(rows, cols, 0.1, 1.0, rng);
    for i in 0..rows {
        for j in 0..cols {
            if rng.uniform() < 0.3 {
                m[(i, j)] = 0.0;
            }
        }
    }
    m
}

#[test]
fn dense_matmul_pool_matches_scoped_spawn() {
    // Bounded by the shared harness: this leg mixes pool dispatch with
    // fresh scoped spawns, so a pool liveness bug would wedge it.
    run_with_timeout("dense pool parity", || {
        for (case, &(rows, n, nh)) in [(37usize, 29usize, 3usize), (64, 51, 1)].iter().enumerate()
        {
            let mut rng = Rng::seed_from(child_seed(0x9001, case as u64));
            let a = Mat::rand_uniform(rows, n, 0.1, 1.0, &mut rng);
            let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
            for t in thread_counts() {
                let got = a.matmul(&x, t);
                let want = scoped_rows(rows, nh, t, |r0, r1| {
                    a.row_block(r0, r1).matmul(&x, 1).as_slice().to_vec()
                });
                assert_bit_identical(
                    got.as_slice(),
                    &want,
                    &format!("dense matmul ({rows}x{n}x{nh}) at {t} threads"),
                );
            }
        }
    });
}

#[test]
fn csr_matmul_pool_matches_scoped_spawn() {
    for (case, &(rows, n, nh)) in [(41usize, 33usize, 4usize), (58, 23, 1)].iter().enumerate() {
        let mut rng = Rng::seed_from(child_seed(0x9002, case as u64));
        let dense = sparse_dense(rows, n, &mut rng);
        let csr = Csr::from_dense(&dense, 0.0);
        let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        for t in thread_counts() {
            let mut got = Mat::zeros(rows, nh);
            csr.matmul_into(&x, &mut got, t);
            let want = scoped_rows(rows, nh, t, |r0, r1| {
                let block = Csr::from_dense(&dense.row_block(r0, r1), 0.0);
                let mut out = Mat::zeros(r1 - r0, nh);
                block.matmul_into(&x, &mut out, 1);
                out.as_slice().to_vec()
            });
            assert_bit_identical(
                got.as_slice(),
                &want,
                &format!("csr matmul ({rows}x{n}x{nh}) at {t} threads"),
            );
        }
    }
}

#[test]
fn log_csr_logsumexp_pool_matches_scoped_spawn() {
    // θ truncation is row-relative, so a row block re-truncated at the
    // same θ keeps exactly the per-row support of the full kernel.
    let theta = -5.0;
    for (case, &(rows, n, nh)) in [(37usize, 31usize, 3usize), (49, 27, 1)].iter().enumerate() {
        let mut rng = Rng::seed_from(child_seed(0x9003, case as u64));
        let a_log = Mat::rand_uniform(rows, n, -8.0, 0.0, &mut rng);
        let lc = LogCsr::from_dense_log(&a_log, theta);
        let x = Mat::rand_uniform(n, nh, -1.0, 1.0, &mut rng);
        for t in thread_counts() {
            let got = lc.logsumexp(&x, t);
            let want = scoped_rows(rows, nh, t, |r0, r1| {
                LogCsr::from_dense_log(&a_log.row_block(r0, r1), theta)
                    .logsumexp(&x, 1)
                    .as_slice()
                    .to_vec()
            });
            assert_bit_identical(
                got.as_slice(),
                &want,
                &format!("log-csr logsumexp ({rows}x{n}x{nh}) at {t} threads"),
            );
        }
    }
}

#[test]
fn absorbed_log_matmul_pool_matches_scoped_spawn() {
    let (theta, covered, sigma) = (-30.0, 2.0, 0.5);
    for (case, &(rows, n, nh)) in [(37usize, 29usize, 3usize), (45, 21, 1)].iter().enumerate() {
        let mut rng = Rng::seed_from(child_seed(0x9004, case as u64));
        let a_log = Mat::rand_uniform(rows, n, -6.0, 0.0, &mut rng);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-0.5, 0.5)).collect();
        let ak = AbsorbedLogCsr::from_dense_log(&a_log, &gref, theta, covered, sigma);
        // Scalings within the covered drift of the reference.
        let xv: Vec<f64> = (0..n * nh)
            .map(|i| gref[i / nh] + rng.uniform_range(-covered, covered))
            .collect();
        let x_log = Mat::from_vec(n, nh, xv);
        for t in thread_counts() {
            let mut ex = Mat::zeros(n, nh);
            let mut lin = Mat::zeros(rows, nh);
            let mut got = Mat::zeros(rows, nh);
            ak.log_matmul_into(&x_log, &mut ex, &mut lin, &mut got, t);
            let want = scoped_rows(rows, nh, t, |r0, r1| {
                let block = a_log.row_block(r0, r1);
                let blk = AbsorbedLogCsr::from_dense_log(&block, &gref, theta, covered, sigma);
                let mut ex = Mat::zeros(n, nh);
                let mut lin = Mat::zeros(r1 - r0, nh);
                let mut out = Mat::zeros(r1 - r0, nh);
                blk.log_matmul_into(&x_log, &mut ex, &mut lin, &mut out, 1);
                out.as_slice().to_vec()
            });
            assert_bit_identical(
                got.as_slice(),
                &want,
                &format!("absorbed log-matmul ({rows}x{n}x{nh}) at {t} threads"),
            );
        }
    }
}

/// Partition `[0, n)` into three uneven column slices (n ≥ 6 here).
fn col_slices(n: usize) -> Vec<(usize, usize)> {
    let (a, b) = (n / 3, n / 2);
    vec![(0, a), (a, b), (b, n)]
}

/// Rows `[c0, c1)` of an `n×nh` flat matrix as an owned slice payload.
fn rows_of(x: &Mat, c0: usize, c1: usize) -> Vec<f64> {
    x.as_slice()[c0 * x.cols()..c1 * x.cols()].to_vec()
}

#[test]
fn dense_fold_matches_batch_at_every_thread_count() {
    let (rows, n, nh) = (37usize, 30usize, 3usize);
    let mut rng = Rng::seed_from(child_seed(0x9005, 0));
    let a = Mat::rand_uniform(rows, n, 0.1, 1.0, &mut rng);
    let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
    let mut batch = Mat::zeros(rows, nh);
    a.matmul_into(&x, &mut batch, 1);
    let fold_at = |t: usize| {
        let mut out = vec![0.0; rows * nh];
        for &(c0, c1) in &col_slices(n) {
            a.matmul_fold(c0, c1 - c0, &rows_of(&x, c0, c1), nh, &mut out, t);
        }
        out
    };
    let serial = fold_at(1);
    let folded = Mat::from_vec(rows, nh, serial.clone());
    assert!(folded.allclose(&batch, 1e-12), "fold != batch (summation-order tolerance)");
    for t in thread_counts() {
        // Banding is per-row, so the fold is bit-stable across counts.
        assert_bit_identical(&fold_at(t), &serial, &format!("dense fold at {t} threads"));
    }
}

#[test]
fn csr_fold_matches_batch_at_every_thread_count() {
    let (rows, n, nh) = (41usize, 27usize, 2usize);
    let mut rng = Rng::seed_from(child_seed(0x9006, 0));
    let dense = sparse_dense(rows, n, &mut rng);
    let csr = Csr::from_dense(&dense, 0.0);
    let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
    let mut batch = Mat::zeros(rows, nh);
    csr.matmul_into(&x, &mut batch, 1);
    let fold_at = |t: usize| {
        let mut out = vec![0.0; rows * nh];
        for &(c0, c1) in &col_slices(n) {
            csr.matmul_fold(c0, c1 - c0, &rows_of(&x, c0, c1), nh, &mut out, t);
        }
        out
    };
    let serial = fold_at(1);
    let folded = Mat::from_vec(rows, nh, serial.clone());
    assert!(folded.allclose(&batch, 1e-12), "csr fold != batch");
    for t in thread_counts() {
        assert_bit_identical(&fold_at(t), &serial, &format!("csr fold at {t} threads"));
    }
}

#[test]
fn log_csr_fold_matches_batch_at_every_thread_count() {
    let (rows, n, nh) = (37usize, 24usize, 3usize);
    let mut rng = Rng::seed_from(child_seed(0x9007, 0));
    let a_log = Mat::rand_uniform(rows, n, -8.0, 0.0, &mut rng);
    let lc = LogCsr::from_dense_log(&a_log, -5.0);
    let x = Mat::rand_uniform(n, nh, -1.0, 1.0, &mut rng);
    let batch = lc.logsumexp(&x, 1);
    let fold_at = |t: usize| {
        let mut mx = vec![f64::NEG_INFINITY; rows * nh];
        let mut sum = vec![0.0; rows * nh];
        for &(c0, c1) in &col_slices(n) {
            lc.logsumexp_fold(c0, c1 - c0, &rows_of(&x, c0, c1), nh, &mut mx, &mut sum, t);
        }
        mx.iter()
            .zip(&sum)
            .map(|(&m, &s)| if s > 0.0 { m + s.ln() } else { f64::NEG_INFINITY })
            .collect::<Vec<f64>>()
    };
    let serial = fold_at(1);
    let folded = Mat::from_vec(rows, nh, serial.clone());
    assert!(folded.allclose(&batch, 1e-12), "log-csr fold != batch");
    for t in thread_counts() {
        assert_bit_identical(&fold_at(t), &serial, &format!("log-csr fold at {t} threads"));
    }
}

#[test]
fn absorbed_fold_matches_batch_at_every_thread_count() {
    let (rows, n, nh) = (37usize, 24usize, 3usize);
    let (theta, covered, sigma) = (-30.0, 2.0, 0.5);
    let mut rng = Rng::seed_from(child_seed(0x9008, 0));
    let a_log = Mat::rand_uniform(rows, n, -6.0, 0.0, &mut rng);
    let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-0.5, 0.5)).collect();
    let ak = AbsorbedLogCsr::from_dense_log(&a_log, &gref, theta, covered, sigma);
    let xv: Vec<f64> = (0..n * nh)
        .map(|i| gref[i / nh] + rng.uniform_range(-covered, covered))
        .collect();
    let x_log = Mat::from_vec(n, nh, xv);
    let mut ex = Mat::zeros(n, nh);
    let mut lin = Mat::zeros(rows, nh);
    let mut batch = Mat::zeros(rows, nh);
    ak.log_matmul_into(&x_log, &mut ex, &mut lin, &mut batch, 1);
    let fold_at = |t: usize| {
        let mut lin = Mat::zeros(rows, nh);
        let mut out = Mat::zeros(rows, nh);
        for &(c0, c1) in &col_slices(n) {
            let slice = rows_of(&x_log, c0, c1);
            assert!(ak.slice_drift(c0, c1 - c0, &slice, nh) <= covered, "drift contract");
            let mut ex_slice = vec![0.0; slice.len()];
            ak.log_matmul_fold(c0, c1 - c0, &slice, nh, &mut ex_slice, &mut lin, t);
        }
        ak.log_matmul_finish(&lin, &mut out);
        out.as_slice().to_vec()
    };
    let serial = fold_at(1);
    let folded = Mat::from_vec(rows, nh, serial.clone());
    assert!(folded.allclose(&batch, 1e-12), "absorbed fold != batch");
    for t in thread_counts() {
        assert_bit_identical(&fold_at(t), &serial, &format!("absorbed fold at {t} threads"));
    }
}
