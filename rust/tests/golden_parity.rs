//! Golden-parity pins for the topology-engine refactor.
//!
//! The four legacy coordinators were collapsed onto one protocol core
//! (`coordinator::engine`): the lock-step client loop, streamed-fold
//! admission, ARQ delivery-class choice, strike-based peer death, and
//! fleet probe/command routing all live in the engine, with each
//! topology reduced to a thin exchange plan. That refactor must be
//! *invisible* in the numbers: the sync protocols are bit-deterministic
//! by design — pure fault schedules, sender-thread-only link state, a
//! row-banded compute pool that splits identically at every width — so
//! any engine regression that reorders a fold, renumbers a wire round,
//! or drops a retransmit shows up here as a flipped mantissa bit.
//!
//! These tests pin the refactored AllToAll and Star lock-step paths to
//! one golden run each: bit-identical scaling iterates and identical
//! iteration counts across compute-thread counts {1, 2, width}, on a
//! lossless fabric AND under a drop/dup/reorder fault plan, at both the
//! exact f64 wire and the lossy-but-reliable deltaf32 wire.

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::net::{FaultPlan, LatencyModel, LinkFault, WireFormat};
use fedsink::sinkhorn::StopPolicy;
use fedsink::workload::{Problem, ProblemSpec};

/// The pinned thread counts: serial, the smallest parallel split, and
/// the machine's full width (deduplicated on narrow CI runners).
fn thread_counts() -> Vec<usize> {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut ts = vec![1, 2, avail];
    ts.sort_unstable();
    ts.dedup();
    ts
}

fn assert_bit_identical(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(g.to_bits() == w.to_bits(), "{what}: index {i} differs: got {g:e}, want {w:e}");
    }
}

fn problem() -> Problem {
    ProblemSpec::new(32).with_eps(0.5).build(0x601D)
}

fn policy(wire: WireFormat) -> StopPolicy {
    // The delta codec reaches tight thresholds too, but its quantized
    // early rounds take longer — give it a softer target and more room.
    match wire {
        WireFormat::F64 => StopPolicy { threshold: 1e-11, max_iters: 1500, ..Default::default() },
        _ => StopPolicy { threshold: 1e-10, max_iters: 4000, ..Default::default() },
    }
}

/// A busy lossy fabric: drops exercise the ARQ fast-forward, dups and
/// reorders the receive-side filters, spikes the latency pricing.
fn lossy_plan(seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        default_link: LinkFault {
            drop_prob: 0.15,
            dup_prob: 0.05,
            reorder_prob: 0.05,
            delay_spike: (0.02, 4.0),
        },
        ..FaultPlan::none()
    }
}

fn cfg(variant: Variant, faults: FaultPlan, wire: WireFormat, threads: usize) -> SolveConfig {
    SolveConfig {
        variant,
        backend: BackendKind::Native,
        clients: 4,
        net: LatencyModel::zero(),
        compute_threads: threads,
        seed: 13,
        wire,
        faults,
        ..Default::default()
    }
}

/// The golden-parity sweep: one baseline run (1 thread, lossless), then
/// every combination of {lossless, faulted} × thread counts must land
/// on the same stop, the same iteration count, and bit-identical u/v.
fn golden_sweep(variant: Variant, wire: WireFormat) {
    let p = problem();
    let name = variant.name();
    let base = run_federated(&p, &cfg(variant, FaultPlan::none(), wire, 1), policy(wire), false);
    assert!(base.converged, "{name} baseline: stop={:?}", base.stop);
    for faulted in [false, true] {
        for t in thread_counts() {
            let plan = if faulted { lossy_plan(21) } else { FaultPlan::none() };
            let out = run_federated(&p, &cfg(variant, plan, wire, t), policy(wire), false);
            let what = format!("{name} ({wire:?}, faulted={faulted}, {t} threads)");
            assert_eq!(out.stop, base.stop, "{what}");
            assert_eq!(out.iterations, base.iterations, "{what}");
            assert_bit_identical(out.state.u.as_slice(), base.state.u.as_slice(), &what);
            assert_bit_identical(out.state.v.as_slice(), base.state.v.as_slice(), &what);
            if faulted {
                assert!(
                    out.traffic.drops > 0 && out.traffic.retransmits > 0,
                    "{what}: the fault plan never fired"
                );
            } else {
                assert_eq!(out.traffic.drops + out.traffic.retransmits, 0, "{what}");
            }
        }
    }
}

#[test]
fn sync_a2a_golden_parity_f64() {
    golden_sweep(Variant::SyncA2A, WireFormat::F64);
}

#[test]
fn sync_star_golden_parity_f64() {
    golden_sweep(Variant::SyncStar, WireFormat::F64);
}

#[test]
fn sync_a2a_golden_parity_deltaf32() {
    // The reliable class never loses a frame, so even the stateful
    // delta codec sees the exact same frame sequence under faults —
    // coded iterates stay bit-identical to the lossless coded run.
    golden_sweep(Variant::SyncA2A, WireFormat::DeltaF32);
}

#[test]
fn sync_star_golden_parity_deltaf32() {
    golden_sweep(Variant::SyncStar, WireFormat::DeltaF32);
}
