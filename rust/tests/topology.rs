//! Ring & gossip topology pins.
//!
//! The two decentralized topologies ride the same protocol engine as
//! the flat coordinators, so their contracts are pinned against the
//! established baselines rather than in isolation:
//!
//! * **Ring** is a rotation AllGather — after c−1 relay hops every node
//!   holds all c slices, so at the exact f64 wire its assembled state
//!   (and therefore every iterate) must be *bit-identical* to the sync
//!   All-to-All run with the same config. Slices ride the reliable ARQ
//!   class, so a chaos plan changes timing and counters, never bits.
//! * **Gossip** is an epidemic push protocol on the latest-wins class:
//!   timing-nondeterministic by design, so its pins are convergence to
//!   the centralized solution within tolerance, chaos survival with
//!   live fault counters, and the purity of the seeded peer schedule
//!   (the one piece that must replay exactly at any thread count).

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::{gossip_peer, run_federated};
use fedsink::net::{FaultPlan, LatencyModel, LinkFault, NodeFault, NodeLoss, Recovery};
use fedsink::sinkhorn::{full_marginal_errors, StopPolicy, StopReason};
use fedsink::testkit::run_with_timeout;
use fedsink::workload::{Problem, ProblemSpec};

/// The pinned thread counts: serial, the smallest parallel split, and
/// the machine's full width (deduplicated on narrow CI runners).
fn thread_counts() -> Vec<usize> {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut ts = vec![1, 2, avail];
    ts.sort_unstable();
    ts.dedup();
    ts
}

fn assert_bit_identical(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(g.to_bits() == w.to_bits(), "{what}: index {i} differs: got {g:e}, want {w:e}");
    }
}

fn problem() -> Problem {
    ProblemSpec::new(32).with_eps(0.5).build(0x2106)
}

fn lossy_plan(seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        default_link: LinkFault {
            drop_prob: 0.15,
            dup_prob: 0.05,
            reorder_prob: 0.05,
            delay_spike: (0.02, 4.0),
        },
        ..FaultPlan::none()
    }
}

fn cfg(variant: Variant, clients: usize) -> SolveConfig {
    SolveConfig {
        variant,
        backend: BackendKind::Native,
        clients,
        alpha: if variant == Variant::Gossip { 0.5 } else { 1.0 },
        net: LatencyModel::zero(),
        compute_threads: 2,
        seed: 17,
        ..Default::default()
    }
}

fn sync_policy() -> StopPolicy {
    StopPolicy { threshold: 1e-11, max_iters: 1500, ..Default::default() }
}

#[test]
fn ring_matches_sync_a2a_bit_for_bit() {
    // The rotation allgather assembles the exact same slice values as
    // the flat allgather (f64 wire copies, never re-encodes), so the
    // two topologies must walk identical iterates to the same stop.
    let p = problem();
    let a2a = run_federated(&p, &cfg(Variant::SyncA2A, 4), sync_policy(), false);
    let ring = run_federated(&p, &cfg(Variant::Ring, 4), sync_policy(), false);
    assert!(a2a.converged, "a2a: stop={:?}", a2a.stop);
    assert_eq!(ring.stop, a2a.stop);
    assert_eq!(ring.iterations, a2a.iterations);
    assert_bit_identical(ring.state.u.as_slice(), a2a.state.u.as_slice(), "ring vs a2a u");
    assert_bit_identical(ring.state.v.as_slice(), a2a.state.v.as_slice(), "ring vs a2a v");
}

#[test]
fn ring_parity_across_thread_counts_and_faults() {
    // The golden-parity discipline extended to the ring: lossless and
    // chaos-plan runs at every thread count land on the same bits —
    // every slice rides the reliable class, so the ARQ reprices the
    // run but never touches a payload.
    let p = problem();
    let run = |faults: FaultPlan, threads: usize| {
        let mut c = cfg(Variant::Ring, 4);
        c.faults = faults;
        c.compute_threads = threads;
        run_federated(&p, &c, sync_policy(), false)
    };
    let base = run(FaultPlan::none(), 1);
    assert!(base.converged, "stop={:?}", base.stop);
    for faulted in [false, true] {
        for t in thread_counts() {
            let plan = if faulted { lossy_plan(33) } else { FaultPlan::none() };
            let out = run(plan, t);
            let what = format!("ring (faulted={faulted}, {t} threads)");
            assert_eq!(out.iterations, base.iterations, "{what}");
            assert_bit_identical(out.state.u.as_slice(), base.state.u.as_slice(), &what);
            assert_bit_identical(out.state.v.as_slice(), base.state.v.as_slice(), &what);
            if faulted {
                assert!(
                    out.traffic.drops > 0 && out.traffic.retransmits > 0,
                    "{what}: chaos plan never fired"
                );
                assert!(!out.degraded, "{what}: no crash injected");
            }
        }
    }
}

#[test]
fn ring_and_gossip_converge_to_the_centralized_solution() {
    let p = problem();
    for clients in [4usize, 8] {
        let central = run_federated(&p, &cfg(Variant::Centralized, clients), sync_policy(), false);
        assert!(central.converged, "centralized: stop={:?}", central.stop);

        let ring = run_federated(&p, &cfg(Variant::Ring, clients), sync_policy(), false);
        assert!(ring.converged, "ring c={clients}: stop={:?}", ring.stop);
        assert!(
            ring.state.u.allclose(&central.state.u, 1e-9)
                && ring.state.v.allclose(&central.state.v, 1e-9),
            "ring c={clients}: iterates drifted from centralized"
        );

        let pol = StopPolicy { threshold: 1e-9, max_iters: 8000, ..Default::default() };
        let gossip = run_federated(&p, &cfg(Variant::Gossip, clients), pol, false);
        assert!(
            gossip.converged,
            "gossip c={clients}: stop={:?} after {} iters",
            gossip.stop,
            gossip.iterations
        );
        // One order looser than the async-a2a pin: gossip views are
        // staler (one push per half-iteration), so the final assembled
        // slices carry more cross-slice lag at the same threshold.
        let (ea, eb) = full_marginal_errors(&p, &gossip.state, 0);
        assert!(ea < 1e-5 && eb < 1e-5, "gossip c={clients}: marginals ({ea}, {eb})");
    }
}

#[test]
fn gossip_survives_chaos_with_live_counters() {
    // Latest-wins pushes genuinely lose dropped frames (no retransmit),
    // but the done votes and the final consistent exchange ride the
    // reliable class — so a chaos run must show both loss *and* ARQ
    // recovery in the counters while still reaching the threshold.
    let p = problem();
    let mut c = cfg(Variant::Gossip, 4);
    c.faults = lossy_plan(5);
    let pol = StopPolicy { threshold: 1e-8, max_iters: 8000, ..Default::default() };
    let out = run_with_timeout("gossip chaos", move || run_federated(&p, &c, pol, false));
    assert!(out.converged, "stop={:?} after {} iters", out.stop, out.iterations);
    assert!(out.traffic.drops > 0, "chaos plan never fired");
    assert!(out.traffic.retransmits > 0, "the reliable finish leg never recovered a drop");
    assert!(!out.degraded && out.lost_nodes.is_empty(), "no crash injected");
}

#[test]
fn ring_neighbor_crash_is_fatal_even_under_exclude() {
    // Every slice transits every link, so a dead neighbor partitions
    // the ring: there is no degrade path, and even `exclude` must abort
    // with a structured PeerLoss — bounded by the recovery budget, not
    // a hang.
    let p = problem();
    let mut c = cfg(Variant::Ring, 4);
    c.faults = FaultPlan {
        nodes: [(1usize, NodeFault { crash_at_iter: Some(3), ..NodeFault::default() })]
            .into_iter()
            .collect(),
        ..FaultPlan::none()
    };
    c.recovery = Recovery { recv_timeout_secs: 0.05, strikes: 2, on_node_loss: NodeLoss::Exclude };
    let pol = StopPolicy { threshold: 1e-11, max_iters: 300, ..Default::default() };
    let out = run_with_timeout("ring crash", move || run_federated(&p, &c, pol, false));
    assert_eq!(out.stop, StopReason::PeerLoss);
    assert!(out.degraded && out.lost_nodes.contains(&1), "lost={:?}", out.lost_nodes);
    assert!(!out.converged);
}

#[test]
fn gossip_node_crash_degrades_gracefully() {
    // Survivors watch the dead node's stamp freeze past the death
    // budget, fold it into the done set, and finish on their own slices
    // — degraded and flagged, never a hang.
    let p = problem();
    let mut c = cfg(Variant::Gossip, 4);
    c.faults = FaultPlan {
        nodes: [(1usize, NodeFault { crash_at_iter: Some(5), ..NodeFault::default() })]
            .into_iter()
            .collect(),
        ..FaultPlan::none()
    };
    c.recovery = Recovery { recv_timeout_secs: 0.05, strikes: 2, on_node_loss: NodeLoss::Exclude };
    let pol = StopPolicy { threshold: 1e-8, max_iters: 600, ..Default::default() };
    let out = run_with_timeout("gossip crash", move || run_federated(&p, &c, pol, false));
    assert!(out.degraded && out.lost_nodes.contains(&1), "lost={:?}", out.lost_nodes);
}

#[test]
fn gossip_peer_schedule_is_pure_and_replays_across_threads() {
    // The push schedule is the only randomized piece of the gossip
    // protocol that must be deterministic: pure in (seed, iter, rank),
    // in-range, never self, and identical no matter which thread
    // computes it.
    let c = 8;
    for seed in [0u64, 17, 0xDEAD] {
        for iter in 1..=200u64 {
            for rank in 0..c {
                let peer = gossip_peer(seed, iter, rank, c);
                assert!(peer < c, "out of range");
                assert_ne!(peer, rank, "a node must never push to itself");
                assert_eq!(peer, gossip_peer(seed, iter, rank, c), "not pure");
            }
        }
    }
    // The schedule varies with the iteration (a frozen push graph could
    // disconnect) and with the seed.
    let varies = (1..=50u64).any(|k| gossip_peer(17, k, 0, c) != gossip_peer(17, k + 1, 0, c));
    assert!(varies, "schedule frozen across iterations");
    let seeded = (1..=50u64).any(|k| gossip_peer(17, k, 0, c) != gossip_peer(18, k, 0, c));
    assert!(seeded, "schedule ignores the seed");
    // Replay across threads: every worker computes the same schedule.
    let golden: Vec<usize> =
        (1..=100u64).flat_map(|k| (0..c).map(move |r| gossip_peer(17, k, r, c))).collect();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                (1..=100u64)
                    .flat_map(|k| (0..c).map(move |r| gossip_peer(17, k, r, c)))
                    .collect::<Vec<usize>>()
            })
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().expect("schedule thread"), golden, "schedule must replay");
    }
}
