//! Privacy regime 2 (paper §II-B + §V): star-network financial risk.
//!
//! A bank group's head office (the star server) holds the market-wide
//! cost structure; country offices hold their local scenario marginals
//! and cannot share them. The group computes the Blanchet–Murthy
//! worst-case expected loss of a shared portfolio with the Federated
//! Sinkhorn inner loop and the Wasserstein-budget λ-search on top.
//!
//! ```sh
//! cargo run --release --example risk_assessment
//! ```

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::finance::{synthetic_portfolio, worst_case_loss, LambdaSearch, WorstCaseSpec};
use fedsink::net::LatencyModel;
use fedsink::sinkhorn::StopPolicy;

fn main() -> anyhow::Result<()> {
    // --- Part 1: the paper's §V-B4 worked example --------------------
    let spec = WorstCaseSpec::paper_example();
    let cfg = SolveConfig {
        variant: Variant::SyncStar,
        backend: BackendKind::Native,
        clients: 3, // three offices, one asset each
        net: LatencyModel::wan(),
        ..Default::default()
    };
    let policy = StopPolicy { threshold: 1e-12, max_iters: 20_000, ..Default::default() };
    let res = worst_case_loss(&spec, &cfg, policy, LambdaSearch::fixed(spec.lambda));
    println!("§V-B4 worked example (3 offices, star network):");
    println!(
        "  ρ_worst = {:+.4} (paper: −0.48) after {} Sinkhorn iterations, {:.3}s",
        res.rho, res.inner_iters, res.secs
    );
    assert!((res.rho - (-0.48)).abs() < 5e-3);

    // --- Part 2: a larger synthetic book with the λ-search -----------
    let scenarios = 96;
    let data = synthetic_portfolio(16, scenarios, 11);
    let spec = WorstCaseSpec {
        returns: data.historical.clone(),
        targets: data.analyst_view.clone(),
        weights: vec![1.0 / scenarios as f64; scenarios],
        lambda: 0.5,
        delta: 0.0,
        eps: 0.01,
        margin: 0.01,
    };
    let cfg = SolveConfig {
        variant: Variant::SyncStar,
        backend: BackendKind::Native,
        clients: 4,
        net: LatencyModel::wan(),
        ..Default::default()
    };
    // Bracket the achievable transport-cost range (cost(λ) is monotone
    // non-increasing), budget δ inside it, then search λ* that spends
    // exactly the budget.
    let (lo_l, hi_l) = (0.01, 16.0);
    let hi_cost = worst_case_loss(&spec, &cfg, policy, LambdaSearch::fixed(lo_l)).transport_cost;
    let lo_cost = worst_case_loss(&spec, &cfg, policy, LambdaSearch::fixed(hi_l)).transport_cost;
    let mut budgeted = spec.clone();
    budgeted.delta = 0.5 * (lo_cost + hi_cost);
    let res = worst_case_loss(
        &budgeted,
        &cfg,
        policy,
        LambdaSearch::bisection(lo_l, hi_l, budgeted.delta * 1e-3, 40),
    );
    println!("\nsynthetic book ({} scenarios across 4 offices):", scenarios);
    println!("  Wasserstein budget δ = {:.6}", budgeted.delta);
    println!(
        "  λ* = {:.4} spends ⟨P,c⟩ = {:.6}; worst-case return ρ = {:+.4} ({} λ-evaluations, {:.2}s)",
        res.lambda, res.transport_cost, res.rho, res.lambda_iters, res.secs
    );
    assert!(res.converged);
    assert!((res.transport_cost - budgeted.delta).abs() < budgeted.delta * 0.05);
    println!("\nrisk assessment OK ✓");
    Ok(())
}
