//! Quickstart: solve one entropic OT problem three ways and check they
//! agree — centralized, synchronous all-to-all, synchronous star.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::net::LatencyModel;
use fedsink::sinkhorn::{full_marginal_errors, objective, transport_plan, StopPolicy};
use fedsink::workload::ProblemSpec;

fn main() -> anyhow::Result<()> {
    // A 256-point problem with Dirichlet marginals and squared-Euclidean
    // cost; ε = 0.05 keeps the plan meaningfully entropic.
    let n = 256;
    let problem = ProblemSpec::new(n).with_eps(0.05).build(7);

    // Prefer the AOT/PJRT backend when this build carries it and the
    // artifacts are built.
    let artifacts = fedsink::config::default_artifacts_dir();
    let backend = if cfg!(feature = "xla-backend")
        && std::path::Path::new(&artifacts).join("manifest.json").exists()
    {
        BackendKind::Xla
    } else {
        eprintln!("no xla runtime/artifacts in this build; using native backend");
        BackendKind::Native
    };

    let policy = StopPolicy { threshold: 1e-11, max_iters: 2000, ..Default::default() };
    let mut plans = Vec::new();

    for (variant, clients) in [
        (Variant::Centralized, 1usize),
        (Variant::SyncA2A, 4),
        (Variant::SyncStar, 4),
    ] {
        let cfg = SolveConfig {
            variant,
            backend,
            clients,
            net: LatencyModel::lan(),
            ..Default::default()
        };
        let out = run_federated(&problem, &cfg, policy, false);
        let (ea, eb) = full_marginal_errors(&problem, &out.state, 0);
        let obj = objective(&problem, &out.state, 0);
        println!(
            "{:<12} c={clients}: {} in {} iters ({:.3}s); marginal errors ({ea:.2e}, {eb:.2e}); objective {obj:.9}",
            variant.name(),
            if out.converged { "converged" } else { "NOT converged" },
            out.iterations,
            out.secs,
        );
        assert!(out.converged);
        plans.push(transport_plan(&problem, &out.state, 0));
    }

    // Prop. 1 in action: all three transport plans coincide.
    for p in &plans[1..] {
        assert!(p.allclose(&plans[0], 1e-8), "plans disagree");
    }
    println!("\nAll three settings produced the same transport plan ✓");
    Ok(())
}
