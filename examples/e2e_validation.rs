//! End-to-end validation driver (DESIGN.md §6) — proves all layers
//! compose: AOT artifacts (L1 Pallas → L2 JAX → HLO) loaded through
//! PJRT, dispatched by the L3 coordinators over the simulated fabric.
//!
//! Workload: a real small problem (n = 512, N = 8 histograms) plus the
//! paper's financial example. Runs centralized + all four federated
//! variants, checks cross-variant agreement to tight tolerance, and
//! reports the paper's headline metrics (iterations, comp/comm split,
//! async convergence rate). Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --features xla-backend --example e2e_validation
//! ```
//!
//! Built without the `xla-backend` feature (the offline default) the
//! driver still validates the full protocol stack on the native kernels.

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::{run_federated, slowest_node};
use fedsink::finance::{worst_case_loss, LambdaSearch, WorstCaseSpec};
use fedsink::net::LatencyModel;
use fedsink::sinkhorn::{full_marginal_errors, StopPolicy};
use fedsink::workload::ProblemSpec;

fn main() -> anyhow::Result<()> {
    let artifacts = fedsink::config::default_artifacts_dir();
    // Prefer the full XLA path when this build carries it *and* the AOT
    // artifacts exist; otherwise validate the stack on the native kernels.
    let have_artifacts =
        std::path::Path::new(&artifacts).join("manifest.json").exists();
    let backend = if cfg!(feature = "xla-backend") && have_artifacts {
        BackendKind::Xla
    } else {
        BackendKind::Native
    };
    println!("=== Federated Sinkhorn end-to-end validation ===");
    println!("artifacts: {artifacts} (backend: {})\n", backend.name());

    // --- Stage 1: n=512, N=8 through the full XLA path ----------------
    let n = 512;
    let nh = 8;
    let problem = ProblemSpec::new(n).with_hists(nh).with_eps(0.05).build(99);
    let policy = StopPolicy { threshold: 1e-10, max_iters: 4000, ..Default::default() };

    println!("stage 1: n={n}, N={nh} histograms, {} backend, LAN fabric", backend.name());
    println!(
        "{:<14} {:>3} {:>6} {:>6} {:>10} {:>10} {:>10} {:>11}",
        "variant", "c", "conv", "iters", "comp(s)", "comm(s)", "total(s)", "err vs ctr"
    );

    // Compare transport *plans*: the scaling state (u, v) is only
    // defined up to the (λu, v/λ) invariance, so plans are the
    // well-defined cross-variant quantity.
    let mut reference: Option<fedsink::linalg::Mat> = None;
    let mut async_ok = 0usize;
    let mut async_runs = 0usize;
    for (variant, clients, alpha) in [
        (Variant::Centralized, 1usize, 1.0),
        (Variant::SyncA2A, 4, 1.0),
        (Variant::SyncStar, 4, 1.0),
        (Variant::AsyncA2A, 4, 0.5),
        (Variant::AsyncStar, 4, 0.5),
    ] {
        let cfg = SolveConfig {
            variant,
            backend,
            clients,
            alpha,
            net: LatencyModel::lan(),
            artifacts_dir: artifacts.clone(),
            ..Default::default()
        };
        let out = run_federated(&problem, &cfg, policy, false);
        let slow = slowest_node(&out.node_stats);
        let plan = fedsink::sinkhorn::transport_plan(&problem, &out.state, 0);
        let dev = match &reference {
            None => {
                reference = Some(plan);
                0.0
            }
            Some(r) => {
                let mut worst: f64 = 0.0;
                for (a, b) in plan.as_slice().iter().zip(r.as_slice()) {
                    worst = worst.max((a - b).abs());
                }
                worst
            }
        };
        if matches!(variant, Variant::AsyncA2A | Variant::AsyncStar) {
            async_runs += 1;
            async_ok += out.converged as usize;
        }
        println!(
            "{:<14} {:>3} {:>6} {:>6} {:>10.3} {:>10.3} {:>10.3} {:>11.2e}",
            variant.name(),
            clients,
            if out.converged { "yes" } else { "NO" },
            out.iterations,
            slow.comp_secs(),
            slow.comm_secs(),
            slow.total_secs(),
            dev
        );
        // Sync variants must match centralized to fp precision; async
        // to the convergence tolerance.
        let (ea, eb) = full_marginal_errors(&problem, &out.state, 0);
        let tol = if alpha < 1.0 { 1e-5 } else { 1e-8 };
        anyhow::ensure!(out.converged, "{} did not converge", variant.name());
        anyhow::ensure!(
            ea < tol && eb < tol,
            "{}: assembled marginals off ({ea:.2e}, {eb:.2e})",
            variant.name()
        );
    }
    println!("async convergence: {async_ok}/{async_runs} runs\n");

    // --- Stage 2: the paper's financial worked example ----------------
    println!("stage 2: Blanchet–Murthy worked example (§V-B4), native backend");
    let spec = WorstCaseSpec::paper_example();
    let cfg = SolveConfig {
        variant: Variant::SyncA2A,
        backend: BackendKind::Native,
        clients: 3,
        net: LatencyModel::lan(),
        ..Default::default()
    };
    let res = worst_case_loss(
        &spec,
        &cfg,
        StopPolicy { threshold: 1e-12, max_iters: 20_000, ..Default::default() },
        LambdaSearch::fixed(spec.lambda),
    );
    println!(
        "  ρ_worst = {:+.4} (paper: −0.48), {} inner iterations, {:.3}s",
        res.rho, res.inner_iters, res.secs
    );
    anyhow::ensure!((res.rho - (-0.48)).abs() < 5e-3, "financial headline off");

    println!("\n=== end-to-end validation PASSED ===");
    Ok(())
}
