//! Privacy regime 1 (paper §II-A): peer-to-peer price alignment.
//!
//! A retail chain's regional branches each hold their own price book and
//! demand profile; legal walls forbid sharing raw prices, but the
//! branches may exchange intermediate Sinkhorn scalings. The aligned
//! price plan is the OT map between the chain-wide current price
//! distribution and the target (harmonized) distribution — computed
//! all-to-all, no coordinator ever seeing a branch's raw data.
//!
//! ```sh
//! cargo run --release --example price_alignment
//! ```

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::linalg::Mat;
use fedsink::net::LatencyModel;
use fedsink::rng::Rng;
use fedsink::sinkhorn::{full_marginal_errors, transport_plan, StopPolicy};
use fedsink::workload::Problem;

fn main() -> anyhow::Result<()> {
    let branches = 4usize;
    let skus_per_branch = 64usize;
    let n = branches * skus_per_branch;
    let mut rng = Rng::seed_from(2026);

    // Each branch's price points cluster around its own market level —
    // branch j's SKUs occupy rows [j*m, (j+1)*m) exactly like Fig 1.
    let mut price_points = Vec::with_capacity(n);
    for b in 0..branches {
        let market_level = 10.0 + 3.0 * b as f64;
        for _ in 0..skus_per_branch {
            price_points.push(market_level + rng.normal_ms(0.0, 1.5));
        }
    }

    // Current demand mass per SKU (a) and the harmonized target (b):
    // the chain wants demand to follow a smooth cross-branch profile.
    let a = rng.dirichlet(n, 2.0);
    let mut b_vec: Vec<f64> = (0..n)
        .map(|i| {
            let t = i as f64 / n as f64;
            (1.0 + (2.0 * std::f64::consts::PI * t).sin().powi(2)) / n as f64
        })
        .collect();
    let s: f64 = b_vec.iter().sum();
    for x in &mut b_vec {
        *x /= s;
    }
    let mut b = Mat::zeros(n, 1);
    for i in 0..n {
        b[(i, 0)] = b_vec[i];
    }

    // Moving demand between price points costs the squared price gap.
    let scale = 1.0 / 100.0; // normalize typical gaps to O(1)
    let mut cost = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let d = (price_points[i] - price_points[j]) * scale;
            cost[(i, j)] = d * d * 10.0;
        }
    }
    let problem = Problem::from_parts(a, b, cost, 0.02);

    // Peer-to-peer solve: each branch is one client.
    let cfg = SolveConfig {
        variant: Variant::SyncA2A,
        backend: BackendKind::Native,
        clients: branches,
        net: LatencyModel::wan(), // branches are geo-distributed
        ..Default::default()
    };
    let policy = StopPolicy { threshold: 1e-10, max_iters: 5000, ..Default::default() };
    let out = run_federated(&problem, &cfg, policy, false);
    let (ea, eb) = full_marginal_errors(&problem, &out.state, 0);
    println!(
        "price alignment across {branches} branches ({n} SKUs): {} in {} iters, errors ({ea:.2e}, {eb:.2e})",
        if out.converged { "converged" } else { "NOT converged" },
        out.iterations
    );
    assert!(out.converged);

    // Per-branch realignment summary: how much demand mass moves out of
    // each branch's price band.
    let plan = transport_plan(&problem, &out.state, 0);
    println!("\n{:>8} {:>16} {:>16}", "branch", "mass kept", "mass moved");
    for bch in 0..branches {
        let (r0, r1) = (bch * skus_per_branch, (bch + 1) * skus_per_branch);
        let mut kept = 0.0;
        let mut moved = 0.0;
        for i in r0..r1 {
            for j in 0..n {
                if (r0..r1).contains(&j) {
                    kept += plan[(i, j)];
                } else {
                    moved += plan[(i, j)];
                }
            }
        }
        println!("{bch:>8} {kept:>16.4} {moved:>16.4}");
    }
    let comm: f64 = out.node_stats.iter().map(|s| s.comm_secs()).sum();
    let comp: f64 = out.node_stats.iter().map(|s| s.comp_secs()).sum();
    println!("\ntotals across nodes: comp {comp:.3}s, comm {comm:.3}s (WAN profile)");
    Ok(())
}
